//! The `SOMS` serving protocol — versioned, length-prefixed request/
//! response frames over TCP or Unix-domain sockets, plus the blocking
//! [`Client`].
//!
//! The wire format mirrors the cluster transport (`SOMW`,
//! `cluster::transport_net`): every frame is `[len: u32 LE][payload]`,
//! and a connection opens with a fixed 8-byte hello
//! `[b"SOMS"][VERSION: u32 LE]` in each direction — the daemon echoes
//! its hello only after validating the client's, so magic and version
//! mismatches are rejected before any frame is parsed.
//!
//! Payloads are a tag byte followed by fields in little-endian byte
//! order; strings and vectors carry a `u32` length/count prefix. The
//! protocol is deliberately not self-describing: both ends are this
//! crate, and the version byte in the hello gates any future layout
//! change.
//!
//! Errors travel as [`Response::Error`] frames carrying the stable
//! [`SomError::code`] string plus the human-readable message, so a
//! client reconstructs the typed error with [`SomError::from_code`].

use std::io::{Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::time::Duration;

use crate::error::SomError;

/// Frame magic for the serving protocol (`SOMW` is the cluster
/// transport; `SOMC` the checkpoint container).
pub const MAGIC: [u8; 4] = *b"SOMS";
/// Protocol version spoken by this build; bumped on any wire change.
pub const VERSION: u32 = 1;
/// Upper bound on one frame's payload (64 MiB — far above any real
/// request; a bigger announced length is a protocol error, not an
/// allocation).
pub const MAX_FRAME: usize = 1 << 26;

// ---------------------------------------------------------------------
// Connections
// ---------------------------------------------------------------------

/// Does this address name a Unix-domain socket (`unix:PATH`)?
pub(crate) fn is_unix(addr: &str) -> bool {
    addr.strip_prefix("unix:").is_some()
}

/// One serving connection: TCP (`host:port`) or Unix (`unix:PATH`).
/// Duplicated from the cluster transport's private enum — the two
/// protocols stay independently versioned.
pub(crate) enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    pub(crate) fn connect(addr: &str) -> Result<Conn, SomError> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                return Ok(Conn::Unix(UnixStream::connect(path)?));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(SomError::config(
                    "unix: addresses need a unix target; use host:port",
                ));
            }
        }
        Ok(Conn::Tcp(TcpStream::connect(addr)?))
    }

    pub(crate) fn set_read_timeout(&self, t: Option<Duration>) -> Result<(), SomError> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t)?,
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(t)?,
        }
        Ok(())
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

/// Write one `[len][payload]` frame.
pub(crate) fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), SomError> {
    if payload.len() > MAX_FRAME {
        return Err(SomError::protocol(format!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte limit",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload. `Ok(None)` = the peer closed the
/// connection cleanly at a frame boundary.
pub(crate) fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, SomError> {
    let mut len = [0u8; 4];
    match r.read(&mut len) {
        Ok(0) => return Ok(None),
        Ok(n) => r.read_exact(&mut len[n..])?,
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(SomError::protocol(format!(
            "announced frame of {len} bytes exceeds the {MAX_FRAME}-byte limit"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// What one read-timeout-bounded poll of a connection produced.
pub(crate) enum FrameEvent {
    /// A complete frame payload.
    Frame(Vec<u8>),
    /// The peer closed the connection at a frame boundary.
    Eof,
    /// The read timeout elapsed with no data — check shutdown and poll
    /// again.
    Idle,
}

/// [`read_frame`] for connections with a read timeout: a timeout while
/// waiting for the *start* of a frame is [`FrameEvent::Idle`] (the
/// daemon's handler loops poll this way so they observe shutdown), a
/// timeout mid-frame is still an error (a stalled half-frame means a
/// broken peer).
pub(crate) fn read_frame_idle(r: &mut impl Read) -> Result<FrameEvent, SomError> {
    let mut len = [0u8; 4];
    match r.read(&mut len) {
        Ok(0) => return Ok(FrameEvent::Eof),
        Ok(n) => r.read_exact(&mut len[n..])?,
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::Interrupted
            ) =>
        {
            return Ok(FrameEvent::Idle)
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(SomError::protocol(format!(
            "announced frame of {len} bytes exceeds the {MAX_FRAME}-byte limit"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(FrameEvent::Frame(payload))
}

/// The 8-byte connection hello.
pub(crate) fn hello_bytes() -> [u8; 8] {
    let mut h = [0u8; 8];
    h[..4].copy_from_slice(&MAGIC);
    h[4..].copy_from_slice(&VERSION.to_le_bytes());
    h
}

/// Validate a peer's hello; distinguishes wrong-protocol from
/// wrong-version so the reject message is actionable.
pub(crate) fn check_hello(h: &[u8; 8]) -> Result<(), SomError> {
    if h[..4] != MAGIC {
        return Err(SomError::protocol(
            "not a somoclu serving connection (bad magic)",
        ));
    }
    let v = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    if v != VERSION {
        return Err(SomError::protocol(format!(
            "protocol version {v} not supported (this daemon speaks {VERSION})"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Payload codec
// ---------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_f32(buf, x);
    }
}

fn put_u32s(buf: &mut Vec<u8>, v: &[u32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u32(buf, x);
    }
}

/// Bounds-checked payload reader; every short read is a typed
/// [`SomError::Protocol`].
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Self {
        Dec { b, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], SomError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(end) => {
                let s = &self.b[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(SomError::protocol("truncated frame payload")),
        }
    }

    fn u8(&mut self) -> Result<u8, SomError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, SomError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, SomError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32, SomError> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().expect("4 bytes")))
    }

    fn f64(&mut self) -> Result<f64, SomError> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().expect("8 bytes")))
    }

    fn str(&mut self) -> Result<String, SomError> {
        let n = self.u32()? as usize;
        let b = self.bytes(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| SomError::protocol("string field is not UTF-8"))
    }

    /// Element-count prefix with a sanity cap implied by the remaining
    /// payload bytes (4 bytes per element), so a hostile count cannot
    /// force a huge allocation.
    fn counted(&mut self, elem_bytes: usize) -> Result<usize, SomError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.b.len() - self.pos {
            return Err(SomError::protocol("element count exceeds payload"));
        }
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>, SomError> {
        let n = self.counted(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn u32s(&mut self) -> Result<Vec<u32>, SomError> {
        let n = self.counted(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn finish(self) -> Result<(), SomError> {
        if self.pos != self.b.len() {
            return Err(SomError::protocol("trailing bytes after payload"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

/// One client request. Vector payloads are row-major f32 (the training
/// data layout); the daemon answers from the currently-hot map.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Best-matching unit of one dense vector.
    Bmu { vector: Vec<f32> },
    /// BMU per row of a dense batch (`data.len() == rows * dim`).
    Project { dim: u32, data: Vec<f32> },
    /// Quantization + topographic error of a dense batch against the
    /// served map.
    Quality { dim: u32, data: Vec<f32> },
    /// Daemon and served-map status.
    Status,
    /// Enqueue a training job; `argv` is a full `somoclu train`
    /// argument vector (flags + INPUT + OUTPUT_PREFIX), validated at
    /// submit time.
    Submit { argv: Vec<String> },
    /// Stream progress events of one job until it finishes.
    Watch { job: u64 },
    /// Ask the daemon to drain and exit (same path as SIGTERM).
    Shutdown,
}

const REQ_BMU: u8 = 1;
const REQ_PROJECT: u8 = 2;
const REQ_QUALITY: u8 = 3;
const REQ_STATUS: u8 = 4;
const REQ_SUBMIT: u8 = 5;
const REQ_WATCH: u8 = 6;
const REQ_SHUTDOWN: u8 = 7;

impl Request {
    /// Serialize to a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Request::Bmu { vector } => {
                b.push(REQ_BMU);
                put_f32s(&mut b, vector);
            }
            Request::Project { dim, data } => {
                b.push(REQ_PROJECT);
                put_u32(&mut b, *dim);
                put_f32s(&mut b, data);
            }
            Request::Quality { dim, data } => {
                b.push(REQ_QUALITY);
                put_u32(&mut b, *dim);
                put_f32s(&mut b, data);
            }
            Request::Status => b.push(REQ_STATUS),
            Request::Submit { argv } => {
                b.push(REQ_SUBMIT);
                put_u32(&mut b, argv.len() as u32);
                for a in argv {
                    put_str(&mut b, a);
                }
            }
            Request::Watch { job } => {
                b.push(REQ_WATCH);
                put_u64(&mut b, *job);
            }
            Request::Shutdown => b.push(REQ_SHUTDOWN),
        }
        b
    }

    /// Parse a frame payload; any malformation is a typed
    /// [`SomError::Protocol`].
    pub fn decode(payload: &[u8]) -> Result<Request, SomError> {
        let mut d = Dec::new(payload);
        let req = match d.u8()? {
            REQ_BMU => Request::Bmu { vector: d.f32s()? },
            REQ_PROJECT => Request::Project {
                dim: d.u32()?,
                data: d.f32s()?,
            },
            REQ_QUALITY => Request::Quality {
                dim: d.u32()?,
                data: d.f32s()?,
            },
            REQ_STATUS => Request::Status,
            REQ_SUBMIT => {
                let n = d.counted(4)?;
                let mut argv = Vec::with_capacity(n);
                for _ in 0..n {
                    argv.push(d.str()?);
                }
                Request::Submit { argv }
            }
            REQ_WATCH => Request::Watch { job: d.u64()? },
            REQ_SHUTDOWN => Request::Shutdown,
            t => {
                return Err(SomError::protocol(format!("unknown request tag {t}")));
            }
        };
        d.finish()?;
        Ok(req)
    }
}

// ---------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------

/// Daemon status snapshot ([`Request::Status`]).
#[derive(Clone, Debug, PartialEq)]
pub struct StatusInfo {
    /// Path of the checkpoint behind the currently-served map ("" when
    /// no map is loaded yet).
    pub checkpoint: String,
    /// Epoch the served map was trained to.
    pub epoch: u64,
    /// Map geometry and data dimensionality (0s when no map is loaded).
    pub rows: u32,
    pub cols: u32,
    pub dim: u32,
    /// Jobs waiting in the queue.
    pub queued_jobs: u32,
    /// The running job's id, or 0 (job ids start at 1).
    pub active_job: u64,
    /// Requests answered since the daemon started.
    pub requests_served: u64,
}

/// One progress event of a training job, streamed to
/// [`Request::Watch`] clients. `Done`/`Failed` are terminal.
#[derive(Clone, Debug, PartialEq)]
pub enum JobEvent {
    /// A training epoch completed.
    Epoch {
        epoch: u64,
        qe: f64,
        radius: f32,
        scale: f32,
    },
    /// The job finished; its final checkpoint is now the served map.
    Done { checkpoint: String },
    /// The job failed with a typed error.
    Failed { code: String, message: String },
    /// The job hit a transient failure (`comm`/`io`/`recovery`) and was
    /// re-queued to resume from its newest checkpoint (`--job-retries`).
    /// Non-terminal: watchers keep streaming through the retry.
    Retry {
        /// Which retry this is (1-based).
        attempt: u32,
        /// The daemon's `--job-retries` budget.
        max: u32,
        /// Stable [`crate::error::SomError::code`] of the failure.
        code: String,
        message: String,
    },
}

impl JobEvent {
    /// Is this a terminal event (no more events will follow)?
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobEvent::Done { .. } | JobEvent::Failed { .. })
    }
}

/// One daemon response.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Answer to [`Request::Bmu`].
    Bmu { node: u64, distance: f32 },
    /// Answer to [`Request::Project`].
    Project { bmus: Vec<u32> },
    /// Answer to [`Request::Quality`].
    Quality { qe: f32, te: f32 },
    /// Answer to [`Request::Status`].
    Status(StatusInfo),
    /// Answer to [`Request::Submit`]: the queued job's id.
    Submitted { job: u64 },
    /// One streamed [`Request::Watch`] event.
    Event { job: u64, event: JobEvent },
    /// Generic success (e.g. [`Request::Shutdown`] acknowledged).
    Ok,
    /// A typed failure: `code` is a stable [`SomError::code`] string.
    Error { code: String, message: String },
}

const RSP_BMU: u8 = 1;
const RSP_PROJECT: u8 = 2;
const RSP_QUALITY: u8 = 3;
const RSP_STATUS: u8 = 4;
const RSP_SUBMITTED: u8 = 5;
const RSP_EVENT: u8 = 6;
const RSP_OK: u8 = 7;
const RSP_ERROR: u8 = 8;

const EV_EPOCH: u8 = 1;
const EV_DONE: u8 = 2;
const EV_FAILED: u8 = 3;
const EV_RETRY: u8 = 4;

impl Response {
    /// Serialize to a frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Response::Bmu { node, distance } => {
                b.push(RSP_BMU);
                put_u64(&mut b, *node);
                put_f32(&mut b, *distance);
            }
            Response::Project { bmus } => {
                b.push(RSP_PROJECT);
                put_u32s(&mut b, bmus);
            }
            Response::Quality { qe, te } => {
                b.push(RSP_QUALITY);
                put_f32(&mut b, *qe);
                put_f32(&mut b, *te);
            }
            Response::Status(s) => {
                b.push(RSP_STATUS);
                put_str(&mut b, &s.checkpoint);
                put_u64(&mut b, s.epoch);
                put_u32(&mut b, s.rows);
                put_u32(&mut b, s.cols);
                put_u32(&mut b, s.dim);
                put_u32(&mut b, s.queued_jobs);
                put_u64(&mut b, s.active_job);
                put_u64(&mut b, s.requests_served);
            }
            Response::Submitted { job } => {
                b.push(RSP_SUBMITTED);
                put_u64(&mut b, *job);
            }
            Response::Event { job, event } => {
                b.push(RSP_EVENT);
                put_u64(&mut b, *job);
                match event {
                    JobEvent::Epoch {
                        epoch,
                        qe,
                        radius,
                        scale,
                    } => {
                        b.push(EV_EPOCH);
                        put_u64(&mut b, *epoch);
                        put_f64(&mut b, *qe);
                        put_f32(&mut b, *radius);
                        put_f32(&mut b, *scale);
                    }
                    JobEvent::Done { checkpoint } => {
                        b.push(EV_DONE);
                        put_str(&mut b, checkpoint);
                    }
                    JobEvent::Failed { code, message } => {
                        b.push(EV_FAILED);
                        put_str(&mut b, code);
                        put_str(&mut b, message);
                    }
                    JobEvent::Retry {
                        attempt,
                        max,
                        code,
                        message,
                    } => {
                        b.push(EV_RETRY);
                        put_u32(&mut b, *attempt);
                        put_u32(&mut b, *max);
                        put_str(&mut b, code);
                        put_str(&mut b, message);
                    }
                }
            }
            Response::Ok => b.push(RSP_OK),
            Response::Error { code, message } => {
                b.push(RSP_ERROR);
                put_str(&mut b, code);
                put_str(&mut b, message);
            }
        }
        b
    }

    /// Parse a frame payload.
    pub fn decode(payload: &[u8]) -> Result<Response, SomError> {
        let mut d = Dec::new(payload);
        let rsp = match d.u8()? {
            RSP_BMU => Response::Bmu {
                node: d.u64()?,
                distance: d.f32()?,
            },
            RSP_PROJECT => Response::Project { bmus: d.u32s()? },
            RSP_QUALITY => Response::Quality {
                qe: d.f32()?,
                te: d.f32()?,
            },
            RSP_STATUS => Response::Status(StatusInfo {
                checkpoint: d.str()?,
                epoch: d.u64()?,
                rows: d.u32()?,
                cols: d.u32()?,
                dim: d.u32()?,
                queued_jobs: d.u32()?,
                active_job: d.u64()?,
                requests_served: d.u64()?,
            }),
            RSP_SUBMITTED => Response::Submitted { job: d.u64()? },
            RSP_EVENT => {
                let job = d.u64()?;
                let event = match d.u8()? {
                    EV_EPOCH => JobEvent::Epoch {
                        epoch: d.u64()?,
                        qe: d.f64()?,
                        radius: d.f32()?,
                        scale: d.f32()?,
                    },
                    EV_DONE => JobEvent::Done {
                        checkpoint: d.str()?,
                    },
                    EV_FAILED => JobEvent::Failed {
                        code: d.str()?,
                        message: d.str()?,
                    },
                    EV_RETRY => JobEvent::Retry {
                        attempt: d.u32()?,
                        max: d.u32()?,
                        code: d.str()?,
                        message: d.str()?,
                    },
                    t => {
                        return Err(SomError::protocol(format!("unknown event tag {t}")))
                    }
                };
                Response::Event { job, event }
            }
            RSP_OK => Response::Ok,
            RSP_ERROR => Response::Error {
                code: d.str()?,
                message: d.str()?,
            },
            t => {
                return Err(SomError::protocol(format!("unknown response tag {t}")));
            }
        };
        d.finish()?;
        Ok(rsp)
    }
}

/// Turn a [`Response::Error`] into the typed error it carried; any
/// other response is an unexpected-response protocol error.
fn expect<T>(got: Response, want: &str, ok: impl FnOnce(Response) -> Option<T>) -> Result<T, SomError> {
    match got {
        Response::Error { code, message } => Err(SomError::from_code(&code, message)),
        other => match ok(other) {
            Some(v) => Ok(v),
            None => Err(SomError::protocol(format!(
                "unexpected response (wanted {want})"
            ))),
        },
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// A blocking serving client: one connection, synchronous
/// request/response. Used by the daemon's tests and available to
/// library consumers; any tool speaking the frame layout above
/// interoperates.
pub struct Client {
    conn: Conn,
}

impl Client {
    /// Connect to a daemon at `host:port` or `unix:PATH` and exchange
    /// hellos. Fails with [`SomError::Protocol`] if the peer speaks a
    /// different protocol or version.
    pub fn connect(addr: &str) -> Result<Client, SomError> {
        let mut conn = Conn::connect(addr)?;
        conn.write_all(&hello_bytes())
            .map_err(|e| SomError::protocol(format!("hello write failed: {e}")))?;
        conn.flush()?;
        let mut h = [0u8; 8];
        conn.read_exact(&mut h)
            .map_err(|e| SomError::protocol(format!("hello read failed: {e}")))?;
        check_hello(&h)?;
        Ok(Client { conn })
    }

    /// Send one request and read one response frame.
    pub fn request(&mut self, req: &Request) -> Result<Response, SomError> {
        write_frame(&mut self.conn, &req.encode())?;
        match read_frame(&mut self.conn)? {
            Some(payload) => Response::decode(&payload),
            None => Err(SomError::protocol("daemon closed the connection")),
        }
    }

    /// BMU of one dense vector: `(node, distance)` — bit-identical to
    /// [`crate::session::SomSession::bmu`] on the served checkpoint.
    pub fn bmu(&mut self, x: &[f32]) -> Result<(usize, f32), SomError> {
        let rsp = self.request(&Request::Bmu { vector: x.to_vec() })?;
        expect(rsp, "bmu", |r| match r {
            Response::Bmu { node, distance } => Some((node as usize, distance)),
            _ => None,
        })
    }

    /// BMU per row of a dense batch.
    pub fn project(&mut self, dim: usize, data: &[f32]) -> Result<Vec<u32>, SomError> {
        let rsp = self.request(&Request::Project {
            dim: dim as u32,
            data: data.to_vec(),
        })?;
        expect(rsp, "project", |r| match r {
            Response::Project { bmus } => Some(bmus),
            _ => None,
        })
    }

    /// Quantization + topographic error of a dense batch: `(qe, te)`.
    pub fn quality(&mut self, dim: usize, data: &[f32]) -> Result<(f32, f32), SomError> {
        let rsp = self.request(&Request::Quality {
            dim: dim as u32,
            data: data.to_vec(),
        })?;
        expect(rsp, "quality", |r| match r {
            Response::Quality { qe, te } => Some((qe, te)),
            _ => None,
        })
    }

    /// Daemon status.
    pub fn status(&mut self) -> Result<StatusInfo, SomError> {
        let rsp = self.request(&Request::Status)?;
        expect(rsp, "status", |r| match r {
            Response::Status(s) => Some(s),
            _ => None,
        })
    }

    /// Enqueue a training job (a full `somoclu train` argv). Returns
    /// the job id; progress streams via [`watch`](Self::watch).
    pub fn submit(&mut self, argv: &[String]) -> Result<u64, SomError> {
        let rsp = self.request(&Request::Submit {
            argv: argv.to_vec(),
        })?;
        expect(rsp, "submitted", |r| match r {
            Response::Submitted { job } => Some(job),
            _ => None,
        })
    }

    /// Start watching a job: the daemon streams [`JobEvent`] frames on
    /// this connection. Read them with [`next_event`](Self::next_event)
    /// until a terminal event; the connection then goes back to
    /// request/response use.
    pub fn watch(&mut self, job: u64) -> Result<(), SomError> {
        write_frame(&mut self.conn, &Request::Watch { job }.encode())
    }

    /// Next streamed event of the job being watched.
    pub fn next_event(&mut self) -> Result<JobEvent, SomError> {
        match read_frame(&mut self.conn)? {
            Some(payload) => match Response::decode(&payload)? {
                Response::Event { event, .. } => Ok(event),
                Response::Error { code, message } => {
                    Err(SomError::from_code(&code, message))
                }
                _ => Err(SomError::protocol("unexpected response (wanted event)")),
            },
            None => Err(SomError::protocol("daemon closed the connection")),
        }
    }

    /// Ask the daemon to drain and exit.
    pub fn shutdown(&mut self) -> Result<(), SomError> {
        let rsp = self.request(&Request::Shutdown)?;
        expect(rsp, "ok", |r| match r {
            Response::Ok => Some(()),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_roundtrip() {
        let reqs = [
            Request::Bmu {
                vector: vec![1.0, -2.5, 3.25],
            },
            Request::Project {
                dim: 3,
                data: vec![0.0; 9],
            },
            Request::Quality {
                dim: 2,
                data: vec![1.0, 2.0],
            },
            Request::Status,
            Request::Submit {
                argv: vec!["-e".into(), "5".into(), "in.txt".into(), "out".into()],
            },
            Request::Watch { job: 42 },
            Request::Shutdown,
        ];
        for r in reqs {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn responses_roundtrip() {
        let rsps = [
            Response::Bmu {
                node: 7,
                distance: 0.5,
            },
            Response::Project {
                bmus: vec![0, 3, 9],
            },
            Response::Quality { qe: 0.1, te: 0.02 },
            Response::Status(StatusInfo {
                checkpoint: "x.somc".into(),
                epoch: 9,
                rows: 5,
                cols: 6,
                dim: 3,
                queued_jobs: 2,
                active_job: 1,
                requests_served: 100,
            }),
            Response::Submitted { job: 3 },
            Response::Event {
                job: 3,
                event: JobEvent::Epoch {
                    epoch: 2,
                    qe: 0.25,
                    radius: 2.0,
                    scale: 0.5,
                },
            },
            Response::Event {
                job: 3,
                event: JobEvent::Done {
                    checkpoint: "job3.somc".into(),
                },
            },
            Response::Event {
                job: 3,
                event: JobEvent::Retry {
                    attempt: 1,
                    max: 3,
                    code: "comm".into(),
                    message: "rank 1 failed".into(),
                },
            },
            Response::Ok,
            Response::Error {
                code: "state".into(),
                message: "no map".into(),
            },
        ];
        for r in rsps {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn malformed_payloads_are_protocol_errors() {
        // Unknown tag.
        assert_eq!(Request::decode(&[200]).unwrap_err().code(), "protocol");
        // Truncated vector.
        let mut b = vec![REQ_BMU];
        b.extend_from_slice(&10u32.to_le_bytes()); // announces 10 floats, has 0
        assert_eq!(Request::decode(&b).unwrap_err().code(), "protocol");
        // Trailing garbage.
        let mut b = Request::Status.encode();
        b.push(0);
        assert_eq!(Request::decode(&b).unwrap_err().code(), "protocol");
        // Empty payload.
        assert_eq!(Request::decode(&[]).unwrap_err().code(), "protocol");
    }

    #[test]
    fn hello_is_checked() {
        assert!(check_hello(&hello_bytes()).is_ok());
        let mut bad_magic = hello_bytes();
        bad_magic[0] = b'X';
        assert_eq!(check_hello(&bad_magic).unwrap_err().code(), "protocol");
        let mut bad_version = hello_bytes();
        bad_version[4] = 99;
        let err = check_hello(&bad_version).unwrap_err();
        assert_eq!(err.code(), "protocol");
        assert!(err.message().contains("version"));
    }

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello").unwrap();
        write_frame(&mut wire, b"").unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap_err().code(), "protocol");
    }
}
