//! The `somoclu serve` daemon: loads a `SOMC` checkpoint, answers
//! `bmu`/`project`/`quality`/`status` requests from concurrent clients,
//! and runs a training job queue whose finished maps hot-swap into the
//! serving slot.
//!
//! Concurrency model: the served map lives behind
//! `RwLock<Option<Arc<ServedMap>>>`. Every request clones the `Arc` and
//! answers from that snapshot, so a publish (an atomic slot swap) never
//! stalls or torments an in-flight request — old readers keep the old
//! map, new readers see the new one, and the old map is freed when the
//! last in-flight request drops it. `bmu` is lock-free over a cloned
//! codebook via [`linear_bmu`] (the *same* arithmetic as
//! [`SomSession::bmu`], so served answers are bit-identical to offline
//! ones); `project`/`quality` go through the map's own `SomSession`
//! under a mutex — the exact offline code path, serialized per map.
//!
//! Shutdown: SIGTERM/SIGINT (when [`ServeOptions::handle_signals`]) or
//! a [`Request::Shutdown`] frame sets one flag. The acceptor stops
//! taking connections, handlers finish their in-flight request and
//! close, watchers get a final `job`-coded error frame, the worker
//! checkpoints and re-queues the in-flight job (see
//! [`super::jobs`]), and the journal makes the next start resume where
//! this one stopped.

use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::DataInput;
use crate::error::SomError;
use crate::serve::jobs::JobQueue;
use crate::serve::protocol::{
    check_hello, hello_bytes, read_frame_idle, write_frame, Conn, FrameEvent, Request,
    Response, StatusInfo,
};
use crate::session::{Som, SomSession};
use crate::som::quality::{linear_bmu, quantization_error, topographic_error};
use crate::som::{Codebook, Grid};

/// How the daemon listens, what it serves first, and where its state
/// lives.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen address: `host:port` (TCP; port 0 picks a free port) or
    /// `unix:PATH`.
    pub addr: String,
    /// Checkpoint to serve from the start; `None` starts empty (reads
    /// fail with a `state` error until a job publishes a map).
    pub checkpoint: Option<PathBuf>,
    /// Queue journal + job checkpoints live here (created if missing).
    pub state_dir: PathBuf,
    /// Worker threads for training jobs and quality computations
    /// (0 = auto, as in training).
    pub threads: usize,
    /// Install SIGTERM/SIGINT handlers that trigger a graceful drain.
    /// The CLI sets this; embedded/test daemons drain via
    /// [`DaemonHandle::stop`] or a shutdown request instead.
    pub handle_signals: bool,
    /// `--job-retries`: restart a training job that fails with a
    /// transient error (`comm`/`io`/`recovery`) from its newest
    /// checkpoint up to this many times (0 = fail on first error).
    pub job_retries: u32,
    /// Log connections and publishes to stderr.
    pub verbose: bool,
}

impl ServeOptions {
    /// Sensible test/embedding defaults: loopback TCP on a free port,
    /// no initial checkpoint, no signal handlers.
    pub fn new(state_dir: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            checkpoint: None,
            state_dir: state_dir.into(),
            threads: 0,
            handle_signals: false,
            job_retries: 0,
            verbose: false,
        }
    }
}

/// One immutable served map: everything a request needs, snapshotted at
/// publish time.
struct ServedMap {
    /// Checkpoint this map came from (pinned against GC while served).
    path: PathBuf,
    /// Cloned codebook for lock-free `bmu` answers.
    codebook: Codebook,
    grid: Grid,
    epoch: u64,
    /// The offline code path for `project`/`quality` — same bits as a
    /// local `SomSession` over the same checkpoint, by construction.
    session: Mutex<SomSession>,
}

impl ServedMap {
    fn load(path: &Path, threads: usize) -> Result<ServedMap, SomError> {
        let mut session = Som::resume(path)?;
        session.set_threads(threads);
        let codebook = session
            .codebook()
            .ok_or_else(|| SomError::checkpoint("checkpoint has no codebook"))?
            .clone();
        let grid = session.grid().clone();
        let epoch = session.epoch() as u64;
        Ok(ServedMap {
            path: path.to_path_buf(),
            codebook,
            grid,
            epoch,
            session: Mutex::new(session),
        })
    }
}

/// State shared by the acceptor, connection handlers, and the worker.
struct Shared {
    served: RwLock<Option<Arc<ServedMap>>>,
    /// Checkpoint paths job GC must never delete (the served one).
    pins: Arc<Mutex<HashSet<PathBuf>>>,
    queue: JobQueue,
    shutdown: AtomicBool,
    requests: AtomicU64,
    threads: usize,
    verbose: bool,
}

impl Shared {
    fn served(&self) -> Result<Arc<ServedMap>, SomError> {
        self.served
            .read()
            .map_err(|_| SomError::internal("served-map slot poisoned"))?
            .clone()
            .ok_or_else(|| {
                SomError::state(
                    "no map is being served yet (start with --checkpoint or submit a job)",
                )
            })
    }

    /// Load `path` and hot-swap it into the serving slot. The new path
    /// is pinned before the swap and the old one unpinned after, so at
    /// no instant is the served checkpoint GC-eligible.
    fn publish(&self, path: &Path) -> Result<(), SomError> {
        let map = Arc::new(ServedMap::load(path, self.threads)?);
        let mut pins = self
            .pins
            .lock()
            .map_err(|_| SomError::internal("pin set poisoned"))?;
        pins.insert(path.to_path_buf());
        let old = self
            .served
            .write()
            .map_err(|_| SomError::internal("served-map slot poisoned"))?
            .replace(map);
        if let Some(old) = old {
            if old.path != path {
                pins.remove(&old.path);
            }
        }
        if self.verbose {
            eprintln!("serve: now serving {}", path.display());
        }
        Ok(())
    }

    fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.notify_all();
    }

    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------
// Listener
// ---------------------------------------------------------------------

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Bind nonblocking (the accept loop polls so it can observe
    /// shutdown). Returns the resolved address — for TCP `:0` that is
    /// the actual port, which tests need.
    fn bind(addr: &str) -> Result<(Listener, String), SomError> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                // A stale socket file from an unclean death blocks
                // rebinding; connect() on it would fail anyway.
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                l.set_nonblocking(true)?;
                return Ok((Listener::Unix(l, PathBuf::from(path)), addr.to_string()));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(SomError::config(
                    "unix: addresses are not supported on this platform; use host:port",
                ));
            }
        }
        let l = TcpListener::bind(addr)?;
        l.set_nonblocking(true)?;
        let resolved = l
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| addr.to_string());
        Ok((Listener::Tcp(l), resolved))
    }

    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Conn::Unix(s))
            }
        }
    }

    fn cleanup(&self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

// ---------------------------------------------------------------------
// Signals
// ---------------------------------------------------------------------

/// Set from the signal handler; the accept loop folds it into the
/// shared shutdown flag. Process-global because signal dispositions
/// are.
static SIGNAL_SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_signal(_sig: i32) {
        SIGNAL_SHUTDOWN.store(true, Ordering::SeqCst);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal as usize);
        signal(SIGINT, on_signal as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

// ---------------------------------------------------------------------
// Daemon
// ---------------------------------------------------------------------

/// A running daemon: the acceptor, its connection handlers, and the job
/// worker. Obtained from [`DaemonHandle::spawn`]; the CLI's blocking
/// entry is [`run`].
pub struct DaemonHandle {
    addr: String,
    shared: Arc<Shared>,
    acceptor: JoinHandle<()>,
    worker: JoinHandle<()>,
}

impl DaemonHandle {
    /// Bind, load the initial checkpoint (if any), replay the job
    /// journal, and start the acceptor + worker threads. Binding and
    /// loading happen synchronously so a bad address or checkpoint
    /// fails here, and [`addr`](Self::addr) is immediately connectable.
    pub fn spawn(opts: ServeOptions) -> Result<DaemonHandle, SomError> {
        let (listener, addr) = Listener::bind(&opts.addr)?;
        let queue = JobQueue::open(&opts.state_dir)?;
        let shared = Arc::new(Shared {
            served: RwLock::new(None),
            pins: Arc::new(Mutex::new(HashSet::new())),
            queue,
            shutdown: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            threads: opts.threads,
            verbose: opts.verbose,
        });
        if let Some(ck) = &opts.checkpoint {
            shared.publish(ck)?;
        }
        if opts.handle_signals {
            install_signal_handlers();
        }
        let acceptor = {
            let shared = Arc::clone(&shared);
            let watch_signals = opts.handle_signals;
            std::thread::spawn(move || accept_loop(shared, listener, watch_signals))
        };
        let worker = {
            let shared = Arc::clone(&shared);
            let job_retries = opts.job_retries;
            std::thread::spawn(move || {
                let publish = |p: &Path| shared.publish(p);
                shared
                    .queue
                    .run_worker(&shared.shutdown, &shared.pins, &publish, job_retries);
            })
        };
        Ok(DaemonHandle {
            addr,
            shared,
            acceptor,
            worker,
        })
    }

    /// The resolved listen address (`host:port` with the real port even
    /// when bound to `:0`, or the `unix:PATH` given).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Request a graceful drain and wait for it to finish: in-flight
    /// requests complete, the running job checkpoints and re-queues,
    /// the journal is flushed.
    pub fn stop(self) -> Result<(), SomError> {
        self.shared.request_shutdown();
        self.join()
    }

    /// Wait for the daemon to exit on its own (a shutdown request or a
    /// handled signal).
    pub fn wait(self) -> Result<(), SomError> {
        self.join()
    }

    fn join(self) -> Result<(), SomError> {
        let mut failed = false;
        failed |= self.acceptor.join().is_err();
        failed |= self.worker.join().is_err();
        if failed {
            return Err(SomError::internal("a daemon thread panicked"));
        }
        Ok(())
    }
}

/// Run a daemon to completion — `somoclu serve`'s blocking body.
/// Returns when a shutdown request or handled signal finishes
/// draining.
pub fn run(opts: ServeOptions) -> Result<(), SomError> {
    let verbose = opts.verbose;
    let handle = DaemonHandle::spawn(opts)?;
    if verbose {
        eprintln!("serve: listening on {}", handle.addr());
    }
    handle.wait()
}

fn accept_loop(shared: Arc<Shared>, listener: Listener, watch_signals: bool) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if watch_signals && SIGNAL_SHUTDOWN.load(Ordering::SeqCst) {
            shared.request_shutdown();
        }
        if shared.draining() {
            break;
        }
        match listener.accept() {
            Ok(conn) => {
                handlers.retain(|h| !h.is_finished());
                let shared = Arc::clone(&shared);
                handlers.push(std::thread::spawn(move || handle_conn(&shared, conn)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                if shared.verbose {
                    eprintln!("serve: accept error: {e}");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    listener.cleanup();
    // Drain: handlers observe the shutdown flag at their next idle poll
    // (in-flight requests finish first).
    for h in handlers {
        let _ = h.join();
    }
}

// ---------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------

fn send(conn: &mut Conn, rsp: &Response) -> bool {
    write_frame(conn, &rsp.encode()).is_ok()
}

fn error_response(e: &SomError) -> Response {
    Response::Error {
        code: e.code().to_string(),
        message: e.message().to_string(),
    }
}

/// One connection's lifetime: hello exchange, then a request/response
/// loop until EOF, a protocol violation, or drain.
fn handle_conn(shared: &Shared, mut conn: Conn) {
    // Hello phase: generous timeout, then reject-before-echo so a
    // client on the wrong protocol or version learns why.
    if conn.set_read_timeout(Some(Duration::from_secs(5))).is_err() {
        return;
    }
    let mut hello = [0u8; 8];
    if conn.read_exact(&mut hello).is_err() {
        return;
    }
    if let Err(e) = check_hello(&hello) {
        let _ = send(&mut conn, &error_response(&e));
        return;
    }
    if conn.write_all(&hello_bytes()).is_err() || conn.flush().is_err() {
        return;
    }
    // Request loop: short read timeouts so an idle connection observes
    // drain promptly.
    if conn
        .set_read_timeout(Some(Duration::from_millis(200)))
        .is_err()
    {
        return;
    }
    loop {
        let payload = match read_frame_idle(&mut conn) {
            Ok(FrameEvent::Frame(p)) => p,
            Ok(FrameEvent::Eof) => return,
            Ok(FrameEvent::Idle) => {
                if shared.draining() {
                    return;
                }
                continue;
            }
            Err(_) => return,
        };
        let req = match Request::decode(&payload) {
            Ok(r) => r,
            Err(e) => {
                // Malformed frame: typed reject, then close — the
                // stream can no longer be trusted to be at a frame
                // boundary.
                let _ = send(&mut conn, &error_response(&e));
                return;
            }
        };
        shared.requests.fetch_add(1, Ordering::Relaxed);
        match req {
            Request::Shutdown => {
                shared.request_shutdown();
                let _ = send(&mut conn, &Response::Ok);
                return;
            }
            Request::Watch { job } => {
                if !stream_job_events(shared, &mut conn, job) {
                    return;
                }
            }
            other => {
                let rsp = answer(shared, other).unwrap_or_else(|e| error_response(&e));
                if !send(&mut conn, &rsp) {
                    return;
                }
            }
        }
    }
}

/// Answer one non-streaming request from the current map snapshot.
fn answer(shared: &Shared, req: Request) -> Result<Response, SomError> {
    match req {
        Request::Bmu { vector } => {
            let map = shared.served()?;
            if vector.len() != map.codebook.dim {
                return Err(SomError::data(format!(
                    "query vector has dim {}, served map has dim {}",
                    vector.len(),
                    map.codebook.dim
                )));
            }
            let (node, distance) = linear_bmu(&map.codebook, &vector);
            Ok(Response::Bmu {
                node: node as u64,
                distance,
            })
        }
        Request::Project { dim, data } => {
            let map = shared.served()?;
            let bmus = project_batch(&map, dim as usize, &data)?;
            Ok(Response::Project { bmus })
        }
        Request::Quality { dim, data } => {
            let map = shared.served()?;
            let dim = dim as usize;
            let bmus = project_batch(&map, dim, &data)?;
            let bmus: Vec<usize> = bmus.iter().map(|&b| b as usize).collect();
            let qe = quantization_error(&data, dim, &map.codebook, &bmus);
            let te =
                topographic_error(&data, dim, &map.grid, &map.codebook, shared.threads);
            Ok(Response::Quality { qe, te })
        }
        Request::Status => {
            let (queued_jobs, active_job) = shared.queue.counts();
            let served = shared
                .served
                .read()
                .map_err(|_| SomError::internal("served-map slot poisoned"))?
                .clone();
            let info = match served {
                Some(m) => StatusInfo {
                    checkpoint: m.path.display().to_string(),
                    epoch: m.epoch,
                    rows: m.grid.rows as u32,
                    cols: m.grid.cols as u32,
                    dim: m.codebook.dim as u32,
                    queued_jobs,
                    active_job,
                    requests_served: shared.requests.load(Ordering::Relaxed),
                },
                None => StatusInfo {
                    checkpoint: String::new(),
                    epoch: 0,
                    rows: 0,
                    cols: 0,
                    dim: 0,
                    queued_jobs,
                    active_job,
                    requests_served: shared.requests.load(Ordering::Relaxed),
                },
            };
            Ok(Response::Status(info))
        }
        Request::Submit { argv } => Ok(Response::Submitted {
            job: shared.queue.submit(argv)?,
        }),
        // Handled by the caller.
        Request::Watch { .. } | Request::Shutdown => {
            Err(SomError::internal("streaming request reached answer()"))
        }
    }
}

/// `project` via the map's own session — the offline code path.
fn project_batch(map: &ServedMap, dim: usize, data: &[f32]) -> Result<Vec<u32>, SomError> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(SomError::data(format!(
            "batch of {} floats is not a whole number of dim-{dim} rows",
            data.len()
        )));
    }
    if dim != map.codebook.dim {
        return Err(SomError::data(format!(
            "batch has dim {dim}, served map has dim {}",
            map.codebook.dim
        )));
    }
    let mut session = map
        .session
        .lock()
        .map_err(|_| SomError::internal("served session poisoned"))?;
    session.project(DataInput::BorrowedF32 { data, dim })
}

/// Stream one job's events until its terminal event. Returns whether
/// the connection is still usable for further requests.
fn stream_job_events(shared: &Shared, conn: &mut Conn, job: u64) -> bool {
    let mut cursor = 0usize;
    loop {
        let (events, done) = match shared.queue.events_since(job, cursor) {
            Some(x) => x,
            None => {
                return send(
                    conn,
                    &error_response(&SomError::job(format!("no such job: {job}"))),
                );
            }
        };
        for event in events {
            cursor += 1;
            if !send(conn, &Response::Event { job, event }) {
                return false;
            }
        }
        // Terminal events are pushed before the status flips, so
        // `done` implies the terminal event was in `events` (or an
        // earlier batch): everything is sent.
        if done {
            return true;
        }
        if shared.draining() {
            let _ = send(
                conn,
                &error_response(&SomError::job(
                    "daemon draining; the job will resume on the next start",
                )),
            );
            return false;
        }
        shared.queue.wait_for_event(Duration::from_millis(200));
    }
}
