//! `somoclu serve` — the checkpoint-serving daemon.
//!
//! Three pieces:
//!
//! - [`protocol`]: the versioned `SOMS` wire protocol (length-prefixed
//!   frames over TCP or Unix sockets) and the blocking [`Client`].
//! - [`daemon`]: the daemon itself — loads a `SOMC` checkpoint, answers
//!   `bmu`/`project`/`quality`/`status` from concurrent connections,
//!   hot-swaps freshly trained maps without dropping in-flight
//!   requests, and drains gracefully on SIGTERM or a shutdown request.
//! - [`jobs`]: the training job queue behind `submit`/`watch` —
//!   journaled to the state directory, resumed from the last checkpoint
//!   after a restart or drain.
//!
//! Start one from the CLI (`somoclu serve 127.0.0.1:9009 --checkpoint
//! map.somc`) or embed it with [`DaemonHandle::spawn`], which binds
//! synchronously and hands back the resolved address — that is what the
//! end-to-end tests do. All errors crossing this API are typed
//! [`crate::error::SomError`] values; over the wire they travel as
//! `(code, message)` pairs and reconstruct on the client side.

pub mod daemon;
pub mod jobs;
pub mod protocol;

pub use daemon::{run, DaemonHandle, ServeOptions};
pub use jobs::{JobQueue, JobStatus};
pub use protocol::{Client, JobEvent, Request, Response, StatusInfo, VERSION};
