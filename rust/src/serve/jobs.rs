//! The daemon's training job queue: submitted `somoclu train` argument
//! vectors run one at a time on a worker thread, stream progress
//! events to watchers, checkpoint into the daemon's state directory,
//! and publish their finished codebook to the hot serving slot.
//!
//! Durability: the queue journals itself to `<state_dir>/queue.json` on
//! every transition (submit, start, finish, fail, drain). On restart
//! the journal is replayed — finished jobs keep their terminal status
//! (so late `watch` requests still resolve), queued jobs re-enter the
//! queue, and a job that was *running* when the daemon died re-enters
//! the queue with `--resume` pointing at its newest cadence checkpoint,
//! so completed epochs are never retrained (resume is bit-exact; see
//! [`crate::session`]).
//!
//! Draining: when shutdown is requested the per-epoch observer returns
//! a typed error, aborting the fit after the epoch in flight; the job
//! re-queues from its newest checkpoint exactly like a crash would, and
//! the journal records that. No partial epoch is ever published.

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::error::SomError;
use crate::io::binary;
use crate::io::output::OutputWriter;
use crate::io::{read_dense, read_sparse, InMemorySource};
use crate::kernels::{DataShard, KernelType};
use crate::serve::protocol::JobEvent;
use crate::session::{checkpoint_path, Som, SomSession};
use crate::som::Codebook;
use crate::util::json::Json;

/// Lifecycle of one submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Waiting in the queue.
    Queued,
    /// Training on the worker thread.
    Running,
    /// Finished; its checkpoint is (or was) the served map.
    Done,
    /// Failed with a typed error (recorded as the terminal event).
    Failed,
}

impl JobStatus {
    fn as_str(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }

    fn from_str(s: &str) -> Option<JobStatus> {
        Some(match s {
            "queued" => JobStatus::Queued,
            "running" => JobStatus::Running,
            "done" => JobStatus::Done,
            "failed" => JobStatus::Failed,
            _ => return None,
        })
    }
}

/// One job's full record (in-memory; the journal persists everything
/// except the event history).
#[derive(Clone, Debug)]
struct JobRecord {
    argv: Vec<String>,
    status: JobStatus,
    events: Vec<JobEvent>,
    /// Newest cadence checkpoint — the resume point after a drain or
    /// crash, and the publish source after success.
    last_checkpoint: Option<PathBuf>,
}

struct QueueState {
    next_id: u64,
    pending: VecDeque<u64>,
    active: Option<u64>,
    jobs: BTreeMap<u64, JobRecord>,
}

/// The training job queue. Shared between the daemon's connection
/// handlers (submit/watch/status) and the single worker thread
/// ([`run_worker`](Self::run_worker)).
pub struct JobQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    state_dir: PathBuf,
}

impl JobQueue {
    /// Open (or create) the queue rooted at `state_dir`, replaying
    /// `queue.json` if present.
    pub fn open(state_dir: &Path) -> Result<JobQueue, SomError> {
        std::fs::create_dir_all(state_dir)?;
        let q = JobQueue {
            state: Mutex::new(QueueState {
                next_id: 1,
                pending: VecDeque::new(),
                active: None,
                jobs: BTreeMap::new(),
            }),
            cv: Condvar::new(),
            state_dir: state_dir.to_path_buf(),
        };
        q.replay_journal()?;
        Ok(q)
    }

    fn journal_path(&self) -> PathBuf {
        self.state_dir.join("queue.json")
    }

    fn replay_journal(&self) -> Result<(), SomError> {
        let path = self.journal_path();
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        let doc = Json::parse(&text).map_err(|e| {
            SomError::job(format!("{}: corrupt queue journal: {e:?}", path.display()))
        })?;
        let bad = || SomError::job(format!("{}: corrupt queue journal", path.display()));
        let mut st = self.state.lock().expect("queue lock");
        st.next_id = doc.get("next_id").and_then(Json::as_usize).ok_or_else(bad)? as u64;
        for j in doc.get("jobs").and_then(Json::as_arr).ok_or_else(bad)? {
            let id = j.get("id").and_then(Json::as_usize).ok_or_else(bad)? as u64;
            let status = j
                .get("status")
                .and_then(Json::as_str)
                .and_then(JobStatus::from_str)
                .ok_or_else(bad)?;
            let argv: Vec<String> = j
                .get("argv")
                .and_then(Json::as_arr)
                .ok_or_else(bad)?
                .iter()
                .map(|a| a.as_str().map(str::to_string).ok_or_else(bad))
                .collect::<Result<_, _>>()?;
            let last_checkpoint = j
                .get("checkpoint")
                .and_then(Json::as_str)
                .map(PathBuf::from)
                // A journaled checkpoint that no longer exists (GC'd by a
                // later run, manual delete) cannot be a resume point.
                .filter(|p| p.exists());
            // A job that was mid-flight when the daemon died re-queues
            // and resumes from its newest surviving checkpoint.
            let status = match status {
                JobStatus::Running => JobStatus::Queued,
                s => s,
            };
            if status == JobStatus::Queued {
                st.pending.push_back(id);
            }
            // The journal does not persist event histories; re-seed the
            // terminal event for finished jobs so a late `watch` still
            // resolves instead of hanging.
            let events = match status {
                JobStatus::Done => vec![JobEvent::Done {
                    checkpoint: last_checkpoint
                        .as_ref()
                        .map(|p| p.display().to_string())
                        .unwrap_or_default(),
                }],
                JobStatus::Failed => vec![JobEvent::Failed {
                    code: "job".to_string(),
                    message: "job failed before a daemon restart (details not journaled)"
                        .to_string(),
                }],
                _ => Vec::new(),
            };
            st.jobs.insert(
                id,
                JobRecord {
                    argv,
                    status,
                    events,
                    last_checkpoint,
                },
            );
        }
        Ok(())
    }

    /// Persist the queue (atomic `.tmp` + rename, like checkpoints).
    /// Called with the lock held by every mutator.
    fn write_journal(&self, st: &QueueState) -> Result<(), SomError> {
        let mut out = String::from("{");
        out.push_str(&format!("\"next_id\": {}, \"jobs\": [", st.next_id));
        let mut first = true;
        for (id, rec) in &st.jobs {
            if !first {
                out.push_str(", ");
            }
            first = false;
            out.push_str(&format!(
                "{{\"id\": {id}, \"status\": {}, \"argv\": [",
                json_str(rec.status.as_str())
            ));
            for (i, a) in rec.argv.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&json_str(a));
            }
            out.push(']');
            if let Some(ck) = &rec.last_checkpoint {
                out.push_str(&format!(
                    ", \"checkpoint\": {}",
                    json_str(&ck.display().to_string())
                ));
            }
            out.push('}');
        }
        out.push_str("]}");
        let path = self.journal_path();
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, out)?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// Validate and enqueue a training job. The argv is parsed with the
    /// `train` subcommand's spec *now*, so a malformed submission fails
    /// at submit time with [`SomError::Job`], not hours later on the
    /// worker.
    pub fn submit(&self, argv: Vec<String>) -> Result<u64, SomError> {
        let opts = parse_job_argv(&argv)?;
        if opts.multiproc.is_some() || opts.config.ranks > 1 {
            return Err(SomError::job(
                "serve jobs are single-process; drop --ranks/--rank/--peers",
            ));
        }
        let mut st = self.state.lock().expect("queue lock");
        let id = st.next_id;
        st.next_id += 1;
        st.jobs.insert(
            id,
            JobRecord {
                argv,
                status: JobStatus::Queued,
                events: Vec::new(),
                last_checkpoint: None,
            },
        );
        st.pending.push_back(id);
        self.write_journal(&st)?;
        drop(st);
        self.cv.notify_all();
        Ok(id)
    }

    /// `(queued, active_job_or_0)` for status reports.
    pub fn counts(&self) -> (u32, u64) {
        let st = self.state.lock().expect("queue lock");
        (st.pending.len() as u32, st.active.unwrap_or(0))
    }

    /// Events of `job` from `cursor` on, plus whether the job is
    /// terminal. `None` = unknown job id.
    pub fn events_since(&self, job: u64, cursor: usize) -> Option<(Vec<JobEvent>, bool)> {
        let st = self.state.lock().expect("queue lock");
        let rec = st.jobs.get(&job)?;
        let evs = rec.events.get(cursor..).unwrap_or(&[]).to_vec();
        let done = matches!(rec.status, JobStatus::Done | JobStatus::Failed);
        Some((evs, done))
    }

    /// Block (bounded by `timeout`) until `job` may have new events.
    pub fn wait_for_event(&self, timeout: Duration) {
        let st = self.state.lock().expect("queue lock");
        let _ = self.cv.wait_timeout(st, timeout);
    }

    /// Wake every waiter (watchers and the worker); the daemon calls
    /// this when shutdown is requested.
    pub fn notify_all(&self) {
        self.cv.notify_all();
    }

    fn push_event(&self, job: u64, ev: JobEvent) {
        let mut st = self.state.lock().expect("queue lock");
        if let Some(rec) = st.jobs.get_mut(&job) {
            rec.events.push(ev);
        }
        drop(st);
        self.cv.notify_all();
    }

    fn set_last_checkpoint(&self, job: u64, path: PathBuf) {
        let mut st = self.state.lock().expect("queue lock");
        if let Some(rec) = st.jobs.get_mut(&job) {
            rec.last_checkpoint = Some(path);
        }
        let _ = self.write_journal(&st);
    }

    fn set_status(&self, job: u64, status: JobStatus) {
        let mut st = self.state.lock().expect("queue lock");
        match status {
            JobStatus::Running => st.active = Some(job),
            _ if st.active == Some(job) => st.active = None,
            _ => {}
        }
        if let Some(rec) = st.jobs.get_mut(&job) {
            rec.status = status;
        }
        let _ = self.write_journal(&st);
        drop(st);
        self.cv.notify_all();
    }

    /// Pop the next queued job, blocking until one arrives or
    /// `shutdown` is set. Returns `(id, argv, resume_from)`.
    fn next_job(&self, shutdown: &AtomicBool) -> Option<(u64, Vec<String>, Option<PathBuf>)> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(id) = st.pending.pop_front() {
                let rec = st.jobs.get(&id).expect("pending job exists");
                return Some((id, rec.argv.clone(), rec.last_checkpoint.clone()));
            }
            let (guard, _) = self
                .cv
                .wait_timeout(st, Duration::from_millis(200))
                .expect("queue lock");
            st = guard;
        }
    }

    /// The worker loop: run queued jobs until `shutdown`. `pins` is the
    /// daemon's GC-protection set (the served checkpoint lives in it);
    /// `publish` swaps a finished job's checkpoint into the hot slot.
    /// `retries` is the daemon's `--job-retries` budget: a job that
    /// fails with a transient error (`comm`/`io`/`recovery`) restarts
    /// from its newest checkpoint up to that many times, each restart
    /// streaming a non-terminal [`JobEvent::Retry`] to watchers.
    ///
    /// Runs on its own thread; returns when shutdown is observed.
    pub fn run_worker(
        &self,
        shutdown: &AtomicBool,
        pins: &Arc<Mutex<HashSet<PathBuf>>>,
        publish: &(dyn Fn(&Path) -> Result<(), SomError> + Sync),
        retries: u32,
    ) {
        while let Some((id, argv, resume_from)) = self.next_job(shutdown) {
            self.set_status(id, JobStatus::Running);
            let mut resume_from = resume_from;
            let mut attempt = 0u32;
            loop {
                match self.run_job(id, &argv, resume_from.clone(), shutdown, pins) {
                    Ok(final_ckpt) => {
                        if let Err(e) = publish(&final_ckpt) {
                            self.push_event(
                                id,
                                JobEvent::Failed {
                                    code: e.code().to_string(),
                                    message: format!("publish failed: {e}"),
                                },
                            );
                            self.set_status(id, JobStatus::Failed);
                            break;
                        }
                        self.set_last_checkpoint(id, final_ckpt.clone());
                        self.push_event(
                            id,
                            JobEvent::Done {
                                checkpoint: final_ckpt.display().to_string(),
                            },
                        );
                        self.set_status(id, JobStatus::Done);
                        break;
                    }
                    Err(e) if e == drain_error() => {
                        // Shutdown mid-job: back to the queue; the journal
                        // records the resume checkpoint for the next start.
                        // The retry count does NOT survive a drain — a
                        // restart gets a fresh budget, like a crash does.
                        self.requeue_front(id);
                        break;
                    }
                    Err(e) if attempt < retries && is_transient(&e) => {
                        attempt += 1;
                        self.push_event(
                            id,
                            JobEvent::Retry {
                                attempt,
                                max: retries,
                                code: e.code().to_string(),
                                message: e.message().to_string(),
                            },
                        );
                        // Completed epochs are never retrained: the next
                        // attempt resumes from the newest checkpoint this
                        // attempt managed to write (journaled, so even a
                        // daemon crash mid-retry keeps it).
                        resume_from = self.last_checkpoint(id);
                        std::thread::sleep(Duration::from_millis(100) * attempt);
                    }
                    Err(e) => {
                        self.push_event(
                            id,
                            JobEvent::Failed {
                                code: e.code().to_string(),
                                message: e.message().to_string(),
                            },
                        );
                        self.set_status(id, JobStatus::Failed);
                        break;
                    }
                }
            }
        }
    }

    /// The newest journaled checkpoint of `job` — the resume point the
    /// next retry attempt starts from.
    fn last_checkpoint(&self, job: u64) -> Option<PathBuf> {
        let st = self.state.lock().expect("queue lock");
        st.jobs.get(&job).and_then(|r| r.last_checkpoint.clone())
    }

    fn requeue_front(&self, id: u64) {
        let mut st = self.state.lock().expect("queue lock");
        if st.active == Some(id) {
            st.active = None;
        }
        if let Some(rec) = st.jobs.get_mut(&id) {
            rec.status = JobStatus::Queued;
        }
        st.pending.push_front(id);
        let _ = self.write_journal(&st);
    }

    /// Train one job to completion. Returns the final checkpoint path
    /// (what the daemon serves next).
    fn run_job(
        &self,
        id: u64,
        argv: &[String],
        resume_from: Option<PathBuf>,
        shutdown: &AtomicBool,
        pins: &Arc<Mutex<HashSet<PathBuf>>>,
    ) -> Result<PathBuf, SomError> {
        let opts = parse_job_argv(argv)?;
        let mut session = build_job_session(&opts, resume_from)?;

        // Checkpoint cadence into the state dir: the user's
        // --checkpoint-every if given, else every epoch — the journal's
        // resume guarantee needs *some* cadence. --keep-last applies;
        // the daemon's pin set shields the served checkpoint.
        let prefix = self.state_dir.join(format!("job{id}"));
        let every = opts.checkpoint_every.max(1);
        session.set_checkpoint_every(every, &prefix);
        session.set_checkpoint_keep_last(opts.keep_last);
        session.set_checkpoint_protected(Arc::clone(pins));

        let result = {
            let mut on_epoch = |s: &SomSession| -> Result<(), SomError> {
                let stats = s.history().last().expect("epoch just finished");
                self.push_event(
                    id,
                    JobEvent::Epoch {
                        epoch: stats.epoch as u64,
                        qe: stats.qe,
                        radius: stats.radius,
                        scale: stats.scale,
                    },
                );
                if s.epoch() % every == 0 {
                    self.set_last_checkpoint(id, checkpoint_path(&prefix, s.epoch()));
                }
                if shutdown.load(Ordering::SeqCst) {
                    return Err(drain_error());
                }
                Ok(())
            };
            run_job_fit(&opts, &mut session, &mut on_epoch)?
        };

        // The job's own outputs (like `somoclu train` writes), then the
        // final checkpoint the daemon will serve.
        let writer = OutputWriter::new(&opts.output_prefix);
        writer.write_final(session.grid(), &result.codebook, &result.bmus, &result.umatrix)?;
        let final_ckpt = self.state_dir.join(format!("job{id}.final.somc"));
        session.save_checkpoint(&final_ckpt)?;
        Ok(final_ckpt)
    }
}

/// The sentinel error a drain aborts the in-flight fit with; compared
/// structurally (SomError is `PartialEq`).
fn drain_error() -> SomError {
    SomError::job("daemon draining; job re-queued at its last checkpoint")
}

/// Is this failure worth a `--job-retries` restart? Only the error
/// classes a retry can plausibly outlive: lost peers and exhausted
/// in-run recovery (`comm`, `recovery`) and I/O hiccups (`io`).
/// Config/data/protocol errors are deterministic — retrying replays
/// the same failure — so they stay terminal.
fn is_transient(e: &SomError) -> bool {
    matches!(e.code(), "comm" | "io" | "recovery")
}

/// Escape a string as a JSON literal (the journal writer; `util::json`
/// only parses).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse a job argv with the `train` subcommand's spec.
fn parse_job_argv(argv: &[String]) -> Result<crate::cli::CliOptions, SomError> {
    let spec = crate::cli::train_spec();
    let parsed = spec
        .parse(argv.iter().cloned())
        .map_err(|e| SomError::job(format!("bad job argv: {e}")))?;
    crate::cli::parse_cli(&parsed).map_err(|e| SomError::job(format!("bad job argv: {e}")))
}

/// Build the session a job trains: a fresh one from its flags, or a
/// resumed one (drain/crash recovery beats the flags' --resume, which
/// beats fresh). Runtime knobs always come from the flags.
fn build_job_session(
    opts: &crate::cli::CliOptions,
    resume_from: Option<PathBuf>,
) -> Result<SomSession, SomError> {
    let resume = resume_from
        .as_ref()
        .map(|p| p.display().to_string())
        .or_else(|| opts.resume.clone());
    if let Some(ckpt) = resume {
        let mut session = Som::resume(&ckpt)?;
        let rt = &opts.config;
        session.set_threads(rt.threads);
        session.set_chunk_rows(rt.chunk_rows);
        session.set_prefetch(rt.prefetch);
        session.set_io_mode(rt.io_mode);
        return Ok(session);
    }
    let grid = opts.config.grid();
    let initial = match &opts.initial_codebook {
        Some(path) => {
            let m = read_dense(path).map_err(|e| SomError::data(format!("{e:#}")))?;
            if m.rows != grid.node_count() {
                return Err(SomError::config(format!(
                    "initial codebook has {} rows, map has {} nodes",
                    m.rows,
                    grid.node_count()
                )));
            }
            Some(Codebook {
                nodes: m.rows,
                dim: m.cols,
                weights: m.data,
            })
        }
        None => None,
    };
    let mut builder = Som::builder().config(opts.config.clone());
    if let Some(cb) = initial {
        builder = builder.initial_codebook(cb);
    }
    builder.build()
}

/// Run a job's fit over the right source for its input (binary
/// containers and `--chunk-rows` stream; text inputs load resident) —
/// the single-process subset of the CLI's dispatch.
fn run_job_fit(
    opts: &crate::cli::CliOptions,
    session: &mut SomSession,
    on_epoch: &mut dyn FnMut(&SomSession) -> Result<(), SomError>,
) -> Result<crate::coordinator::train::TrainResult, SomError> {
    let cfg = session.config().clone();
    let binary_kind = binary::sniff(&opts.input_file)
        .map_err(|e| SomError::data(format!("{}: {e:#}", opts.input_file)))?;
    if cfg.chunk_rows > 0 || binary_kind.is_some() {
        let mut src = crate::io::open_stream_source(
            &opts.input_file,
            binary_kind,
            cfg.kernel,
            cfg.chunk_rows,
            cfg.prefetch,
            cfg.io_mode,
            true, // quiet: the daemon's log is the event stream
        )?;
        session.fit_source_with(&mut *src, on_epoch)
    } else if cfg.kernel == KernelType::SparseCpu {
        let m = read_sparse(&opts.input_file, 0).map_err(|e| SomError::data(format!("{e:#}")))?;
        let mut src = InMemorySource::new(DataShard::Sparse(m.view()), cfg.chunk_rows);
        session.fit_source_with(&mut src, on_epoch)
    } else {
        let m = read_dense(&opts.input_file).map_err(|e| SomError::data(format!("{e:#}")))?;
        let mut src = InMemorySource::new(
            DataShard::Dense {
                data: &m.data,
                dim: m.cols,
            },
            cfg.chunk_rows,
        );
        session.fit_source_with(&mut src, on_epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "somoclu-jobs-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn submit_validates_argv() {
        let dir = tmpdir("validate");
        let q = JobQueue::open(&dir).unwrap();
        // Missing positionals.
        assert_eq!(
            q.submit(vec!["-e".into(), "3".into()]).unwrap_err().code(),
            "job"
        );
        // Multi-rank jobs are refused.
        let err = q
            .submit(vec!["--ranks".into(), "2".into(), "in".into(), "out".into()])
            .unwrap_err();
        assert_eq!(err.code(), "job");
        // A well-formed argv queues.
        let id = q.submit(vec!["in.txt".into(), "out".into()]).unwrap();
        assert_eq!(id, 1);
        assert_eq!(q.counts(), (1, 0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_roundtrips_queue_state() {
        let dir = tmpdir("journal");
        {
            let q = JobQueue::open(&dir).unwrap();
            q.submit(vec!["a.txt".into(), "out-a".into()]).unwrap();
            q.submit(vec![
                "-e".into(),
                "7".into(),
                "b \"quoted\"\n.txt".into(),
                "out-b".into(),
            ])
            .unwrap();
            q.set_status(1, JobStatus::Running);
        }
        // Reopen: job 1 (running at "crash") re-queues, job 2 stays
        // queued; ids and argv survive, including escaped characters.
        let q = JobQueue::open(&dir).unwrap();
        let st = q.state.lock().unwrap();
        assert_eq!(st.next_id, 3);
        assert_eq!(st.pending, VecDeque::from([1, 2]));
        assert_eq!(st.jobs[&1].status, JobStatus::Queued);
        assert_eq!(st.jobs[&2].argv[2], "b \"quoted\"\n.txt");
        drop(st);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn events_and_counts_flow() {
        let dir = tmpdir("events");
        let q = JobQueue::open(&dir).unwrap();
        let id = q.submit(vec!["in.txt".into(), "out".into()]).unwrap();
        q.push_event(
            id,
            JobEvent::Epoch {
                epoch: 0,
                qe: 0.5,
                radius: 2.0,
                scale: 1.0,
            },
        );
        let (evs, done) = q.events_since(id, 0).unwrap();
        assert_eq!(evs.len(), 1);
        assert!(!done);
        let (evs, _) = q.events_since(id, 1).unwrap();
        assert!(evs.is_empty());
        assert!(q.events_since(99, 0).is_none());
        q.set_status(id, JobStatus::Done);
        let (_, done) = q.events_since(id, 0).unwrap();
        assert!(done);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// End-to-end worker retry: training succeeds but the final output
    /// write hits a transient `io` error every attempt (the output
    /// prefix points into a directory that does not exist). With a
    /// budget of 2 the worker streams Retry{1,2} and Retry{2,2}, never
    /// retrains a completed epoch (attempts 2 and 3 resume from the
    /// journaled checkpoint), and lands on a terminal `io` failure.
    #[test]
    fn worker_retries_transient_failures_until_budget() {
        let dir = tmpdir("retry");
        let input = dir.join("in.txt");
        let mut text = String::new();
        for i in 0..12 {
            let v = i as f32;
            text.push_str(&format!("{} {} {}\n", v, v * 0.5, 12.0 - v));
        }
        std::fs::write(&input, text).unwrap();
        let out = dir.join("no-such-dir").join("out");

        let q = JobQueue::open(&dir).unwrap();
        let id = q
            .submit(vec![
                "-e".into(),
                "2".into(),
                "-x".into(),
                "3".into(),
                "-y".into(),
                "3".into(),
                input.display().to_string(),
                out.display().to_string(),
            ])
            .unwrap();

        let shutdown = AtomicBool::new(false);
        let pins = Arc::new(Mutex::new(HashSet::new()));
        std::thread::scope(|s| {
            s.spawn(|| {
                let publish = |_: &Path| Ok(());
                q.run_worker(&shutdown, &pins, &publish, 2);
            });
            loop {
                let (_, done) = q.events_since(id, 0).unwrap();
                if done {
                    break;
                }
                q.wait_for_event(Duration::from_millis(50));
            }
            shutdown.store(true, Ordering::SeqCst);
            q.notify_all();
        });

        let (events, done) = q.events_since(id, 0).unwrap();
        assert!(done);
        let retries: Vec<_> = events
            .iter()
            .filter_map(|e| match e {
                JobEvent::Retry { attempt, max, code, .. } => {
                    Some((*attempt, *max, code.as_str()))
                }
                _ => None,
            })
            .collect();
        assert_eq!(retries, vec![(1, 2, "io"), (2, 2, "io")]);
        // Attempts 2 and 3 resumed from the epoch-2 checkpoint, so only
        // the first attempt trained epochs.
        let epochs = events
            .iter()
            .filter(|e| matches!(e, JobEvent::Epoch { .. }))
            .count();
        assert_eq!(epochs, 2);
        match events.last().unwrap() {
            JobEvent::Failed { code, .. } => assert_eq!(code, "io"),
            other => panic!("expected a terminal failure, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn json_str_escapes() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }
}
