//! `SomError` — the typed error surface of the crate (ISSUE 8).
//!
//! Every error that crosses the public session/serve boundary is a
//! [`SomError`]: a closed set of categories, each with a **stable
//! machine-readable code** ([`SomError::code`]) and a human-readable
//! message. The codes are part of the serving wire protocol
//! ([`crate::serve::protocol`]) — a remote client sees exactly the same
//! category a local library caller matches on — so they are frozen:
//! codes may be added, never renamed or removed.
//!
//! | variant        | code         | meaning                                         |
//! |----------------|--------------|-------------------------------------------------|
//! | `Config`       | `config`     | invalid or inconsistent configuration           |
//! | `State`        | `state`      | operation needs state the session does not have |
//! | `Data`         | `data`       | input data malformed or mismatched (dims, rows) |
//! | `Io`           | `io`         | operating-system I/O failure                    |
//! | `Checkpoint`   | `checkpoint` | unreadable, corrupt, or mismatched `SOMC` file  |
//! | `Comm`         | `comm`       | cluster communication failure (rank lost, ...)  |
//! | `Recovery`     | `recovery`   | rank-failure recovery exhausted its restarts    |
//! | `Protocol`     | `protocol`   | malformed or version-mismatched serve request   |
//! | `Job`          | `job`        | training-job queue failure                      |
//! | `Internal`     | `internal`   | anything not classified above                   |
//!
//! Internals (kernels, collectives, format decoders) still compose
//! errors with `anyhow`; the `From<anyhow::Error>` impl classifies a
//! chain as it crosses the public boundary — an embedded `SomError`
//! keeps its category, a [`CommError`] chain becomes `Comm`, an
//! [`std::io::Error`] chain becomes `Io`, everything else `Internal`.
//! The full `{:#}`-style context chain is flattened into the message,
//! so no diagnostic text is lost in the translation.

use crate::cluster::comm::CommError;

/// The crate's public error type: one category per failure class, each
/// with a stable wire code. See the [module docs](self) for the table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SomError {
    /// Invalid or inconsistent configuration (`TrainConfig::validate`,
    /// builder misuse, contradictory CLI flags).
    Config(String),
    /// The operation needs state the session does not have yet (no
    /// codebook before `fit`/`resume`, nothing to checkpoint, ...).
    State(String),
    /// Input data malformed or mismatched: wrong dimensionality, zero
    /// rows, unparseable rows.
    Data(String),
    /// Operating-system I/O failure (open/read/write/bind).
    Io(String),
    /// A `SOMC` checkpoint could not be read, failed validation
    /// (magic/version/checksum/length), or could not be written.
    Checkpoint(String),
    /// Cluster communication failure (peer lost mid-collective,
    /// undecodable collective payload).
    Comm(String),
    /// Automatic rank-failure recovery ran out of restarts (ISSUE 10):
    /// a communication failure persisted through every retry the
    /// [`RecoveryPolicy`](crate::cluster::fault::RecoveryPolicy)
    /// allowed. The message carries the root-cause abort (failed rank,
    /// epoch, rewind point) — the detail layer on top of `Comm`.
    Recovery(String),
    /// Malformed or version-mismatched serve-protocol request/response.
    Protocol(String),
    /// Training-job queue failure (unparseable job spec, journal
    /// corruption, job aborted by drain).
    Job(String),
    /// Unclassified internal failure (the escape hatch for errors that
    /// do not fit a category; the message carries the full chain).
    Internal(String),
}

impl SomError {
    /// Build the variant for a stable `code` string; unknown codes map
    /// to [`SomError::Internal`] (the wire client uses this to
    /// reconstruct errors, so a newer server's new code degrades to
    /// `internal` instead of failing the decode).
    pub fn from_code(code: &str, message: impl Into<String>) -> SomError {
        let message = message.into();
        match code {
            "config" => SomError::Config(message),
            "state" => SomError::State(message),
            "data" => SomError::Data(message),
            "io" => SomError::Io(message),
            "checkpoint" => SomError::Checkpoint(message),
            "comm" => SomError::Comm(message),
            "recovery" => SomError::Recovery(message),
            "protocol" => SomError::Protocol(message),
            "job" => SomError::Job(message),
            _ => SomError::Internal(message),
        }
    }

    /// The stable machine-readable code for this category — what the
    /// serve protocol puts on the wire and scripts match on. Frozen:
    /// codes are never renamed.
    pub fn code(&self) -> &'static str {
        match self {
            SomError::Config(_) => "config",
            SomError::State(_) => "state",
            SomError::Data(_) => "data",
            SomError::Io(_) => "io",
            SomError::Checkpoint(_) => "checkpoint",
            SomError::Comm(_) => "comm",
            SomError::Recovery(_) => "recovery",
            SomError::Protocol(_) => "protocol",
            SomError::Job(_) => "job",
            SomError::Internal(_) => "internal",
        }
    }

    /// The human-readable message (without the code prefix).
    pub fn message(&self) -> &str {
        match self {
            SomError::Config(m)
            | SomError::State(m)
            | SomError::Data(m)
            | SomError::Io(m)
            | SomError::Checkpoint(m)
            | SomError::Comm(m)
            | SomError::Recovery(m)
            | SomError::Protocol(m)
            | SomError::Job(m)
            | SomError::Internal(m) => m,
        }
    }

    /// Shorthand constructors (each takes anything `Into<String>`).
    pub fn config(m: impl Into<String>) -> SomError {
        SomError::Config(m.into())
    }
    /// See [`SomError::State`].
    pub fn state(m: impl Into<String>) -> SomError {
        SomError::State(m.into())
    }
    /// See [`SomError::Data`].
    pub fn data(m: impl Into<String>) -> SomError {
        SomError::Data(m.into())
    }
    /// See [`SomError::Io`].
    pub fn io(m: impl Into<String>) -> SomError {
        SomError::Io(m.into())
    }
    /// See [`SomError::Checkpoint`].
    pub fn checkpoint(m: impl Into<String>) -> SomError {
        SomError::Checkpoint(m.into())
    }
    /// See [`SomError::Recovery`].
    pub fn recovery(m: impl Into<String>) -> SomError {
        SomError::Recovery(m.into())
    }
    /// See [`SomError::Protocol`].
    pub fn protocol(m: impl Into<String>) -> SomError {
        SomError::Protocol(m.into())
    }
    /// See [`SomError::Job`].
    pub fn job(m: impl Into<String>) -> SomError {
        SomError::Job(m.into())
    }
    /// See [`SomError::Internal`].
    pub fn internal(m: impl Into<String>) -> SomError {
        SomError::Internal(m.into())
    }
}

impl std::fmt::Display for SomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // The message alone: messages are written self-describing, and
        // test/CLI consumers match on their text. The code is exposed
        // separately via `code()` (and the serve wire format).
        f.write_str(self.message())
    }
}

impl std::error::Error for SomError {}

impl From<std::io::Error> for SomError {
    fn from(e: std::io::Error) -> SomError {
        SomError::Io(e.to_string())
    }
}

impl From<CommError> for SomError {
    fn from(e: CommError) -> SomError {
        SomError::Comm(e.to_string())
    }
}

impl From<anyhow::Error> for SomError {
    fn from(e: anyhow::Error) -> SomError {
        // An outermost SomError passes through untouched.
        let e = match e.downcast::<SomError>() {
            Ok(s) => return s,
            Err(e) => e,
        };
        // Otherwise classify by the deepest recognizable cause, keeping
        // the whole `{:#}` context chain as the message.
        let msg = format!("{e:#}");
        for cause in e.chain() {
            if let Some(s) = cause.downcast_ref::<SomError>() {
                return SomError::from_code(s.code(), msg);
            }
            if cause.is::<CommError>() {
                return SomError::Comm(msg);
            }
            if cause.is::<std::io::Error>() {
                return SomError::Io(msg);
            }
        }
        SomError::Internal(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable() {
        let cases = [
            (SomError::config("x"), "config"),
            (SomError::state("x"), "state"),
            (SomError::data("x"), "data"),
            (SomError::io("x"), "io"),
            (SomError::checkpoint("x"), "checkpoint"),
            (SomError::Comm("x".into()), "comm"),
            (SomError::recovery("x"), "recovery"),
            (SomError::protocol("x"), "protocol"),
            (SomError::job("x"), "job"),
            (SomError::internal("x"), "internal"),
        ];
        for (err, code) in cases {
            assert_eq!(err.code(), code);
            // from_code round-trips every known code.
            assert_eq!(SomError::from_code(code, "x"), err);
        }
        // Unknown codes degrade to internal, not a decode failure.
        assert_eq!(SomError::from_code("galaxy", "m").code(), "internal");
    }

    #[test]
    fn anyhow_classification() {
        // Embedded SomError keeps its category through a context chain.
        let e: anyhow::Error = anyhow::Error::new(SomError::data("dim mismatch"));
        assert_eq!(SomError::from(e).code(), "data");
        let e = anyhow::Error::new(SomError::checkpoint("bad magic"))
            .context("resuming run");
        let s = SomError::from(e);
        assert_eq!(s.code(), "checkpoint");
        assert!(s.message().contains("resuming run"), "{s}");
        assert!(s.message().contains("bad magic"), "{s}");

        // io::Error chains classify as io.
        let e = anyhow::Error::new(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ))
        .context("opening data");
        assert_eq!(SomError::from(e).code(), "io");

        // CommError chains classify as comm.
        let e = anyhow::Error::new(crate::cluster::comm::CommError::PeerLost {
            peer: 3,
        })
        .context("epoch 5");
        let s = SomError::from(e);
        assert_eq!(s.code(), "comm");
        assert!(s.message().contains("rank 3"), "{s}");

        // Anything else is internal, message preserved.
        let s = SomError::from(anyhow::anyhow!("kernel exploded"));
        assert_eq!(s.code(), "internal");
        assert_eq!(s.message(), "kernel exploded");
    }

    #[test]
    fn displays_message_only() {
        let e = SomError::config("epochs must be > 0");
        assert_eq!(e.to_string(), "epochs must be > 0");
        // And it is a std error, so anyhow absorbs it.
        fn absorbs() -> anyhow::Result<()> {
            Err(anyhow::Error::new(SomError::state("no codebook")))
        }
        let err = absorbs().unwrap_err();
        assert_eq!(err.downcast_ref::<SomError>().unwrap().code(), "state");
    }
}
