//! Synthetic workload generators for the paper's experiments.
//!
//! * `random_dense` — the Fig. 5/7/8 workload: "the data elements were
//!   randomly generated, as we were interested in scalability alone".
//! * `gaussian_blobs` — clustered data for convergence tests and the
//!   quickstart example.
//! * `rgb_toy` — the classic RGB clustering toy set (Figs. 2–4).
//! * `zipf_corpus` — Fig. 9 stand-in: a Zipfian term-document space with
//!   planted topics (~1–5% density, like the Reuters-21578 vector space;
//!   see DESIGN.md §3 substitutions).

use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Uniform random dense rows in [0, 1) — the scalability benchmark data.
pub fn random_dense(rows: usize, dim: usize, rng: &mut Rng) -> Vec<f32> {
    (0..rows * dim).map(|_| rng.f32()).collect()
}

/// Isotropic Gaussian blobs around `k` random centers; returns (data,
/// labels).
pub fn gaussian_blobs(
    rows: usize,
    dim: usize,
    k: usize,
    spread: f32,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<usize>) {
    assert!(k > 0);
    let centers: Vec<f32> = (0..k * dim).map(|_| rng.range_f32(-3.0, 3.0)).collect();
    let mut data = Vec::with_capacity(rows * dim);
    let mut labels = Vec::with_capacity(rows);
    for r in 0..rows {
        let c = r % k;
        for d in 0..dim {
            data.push(centers[c * dim + d] + spread * rng.normal_f32());
        }
        labels.push(c);
    }
    (data, labels)
}

/// RGB toy set: `rows` colors drawn near `k` primary anchors (the toy
/// example the paper's Figs. 2–4 visualize). dim = 3.
pub fn rgb_toy(rows: usize, rng: &mut Rng) -> (Vec<f32>, Vec<usize>) {
    const ANCHORS: [[f32; 3]; 6] = [
        [1.0, 0.0, 0.0],
        [0.0, 1.0, 0.0],
        [0.0, 0.0, 1.0],
        [1.0, 1.0, 0.0],
        [0.0, 1.0, 1.0],
        [1.0, 0.0, 1.0],
    ];
    let mut data = Vec::with_capacity(rows * 3);
    let mut labels = Vec::with_capacity(rows);
    for r in 0..rows {
        let a = r % ANCHORS.len();
        for d in 0..3 {
            data.push((ANCHORS[a][d] + 0.12 * rng.normal_f32()).clamp(0.0, 1.0));
        }
        labels.push(a);
    }
    (data, labels)
}

/// Synthetic sparse term-document corpus with planted topics.
///
/// Each document draws `nnz_per_row` distinct terms: a fraction from its
/// topic's preferred band of the vocabulary, the rest from a global
/// Zipfian background. tf-idf-like weights in (0, 1]. This reproduces
/// the *structure* Fig. 9 visualizes: dense semantic clusters separated
/// by sparse barriers.
pub struct CorpusSpec {
    pub docs: usize,
    pub vocab: usize,
    pub topics: usize,
    pub nnz_per_row: usize,
    /// Probability that a term comes from the document's topic band.
    pub topic_affinity: f64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            docs: 2000,
            vocab: 4096,
            topics: 8,
            nnz_per_row: 50,
            topic_affinity: 0.7,
        }
    }
}

pub fn zipf_corpus(spec: &CorpusSpec, rng: &mut Rng) -> (Csr, Vec<usize>) {
    assert!(spec.topics > 0 && spec.vocab >= spec.topics);
    let band = spec.vocab / spec.topics;
    let mut rows = Vec::with_capacity(spec.docs);
    let mut labels = Vec::with_capacity(spec.docs);
    for doc in 0..spec.docs {
        let topic = doc % spec.topics;
        let mut cols = std::collections::BTreeMap::new();
        // Rejection-free: sample until we have nnz distinct terms.
        let mut guard = 0;
        while cols.len() < spec.nnz_per_row.min(spec.vocab) && guard < 100_000 {
            guard += 1;
            let term = if rng.f64() < spec.topic_affinity {
                // Zipf *within* the topic band: topical head terms.
                topic * band + rng.zipf(band.max(1), 1.1)
            } else {
                rng.zipf(spec.vocab, 1.1)
            };
            let weight = (0.1 + 0.9 * rng.f32()).min(1.0);
            cols.entry(term as u32).or_insert(weight);
        }
        rows.push(cols.into_iter().collect::<Vec<_>>());
        labels.push(topic);
    }
    let m = Csr::from_rows(rows, spec.vocab).expect("distinct sorted cols");
    (m, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_dense_shape_and_range() {
        let mut rng = Rng::new(1);
        let d = random_dense(10, 5, &mut rng);
        assert_eq!(d.len(), 50);
        assert!(d.iter().all(|v| (0.0..1.0).contains(v)));
    }

    #[test]
    fn blobs_cluster_tightly() {
        let mut rng = Rng::new(2);
        let (data, labels) = gaussian_blobs(200, 4, 4, 0.05, &mut rng);
        assert_eq!(labels.len(), 200);
        // Same-label rows are near each other; cross-label rows far.
        let row = |r: usize| &data[r * 4..(r + 1) * 4];
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        let same = d(row(0), row(4)); // labels 0 and 0
        let diff = d(row(0), row(1)); // labels 0 and 1
        assert!(same < diff, "{same} vs {diff}");
    }

    #[test]
    fn rgb_toy_in_unit_cube() {
        let mut rng = Rng::new(3);
        let (data, labels) = rgb_toy(60, &mut rng);
        assert_eq!(data.len(), 180);
        assert_eq!(labels.iter().max(), Some(&5));
        assert!(data.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn corpus_density_in_paper_band() {
        let mut rng = Rng::new(4);
        let spec = CorpusSpec {
            docs: 200,
            vocab: 2048,
            nnz_per_row: 40,
            ..Default::default()
        };
        let (m, labels) = zipf_corpus(&spec, &mut rng);
        assert_eq!(m.rows, 200);
        assert_eq!(labels.len(), 200);
        // ~40/2048 ≈ 2% — inside the paper's "1–5% nonzero" band.
        assert!(
            (0.01..=0.05).contains(&m.density()),
            "density {}",
            m.density()
        );
    }

    #[test]
    fn corpus_topics_share_terms() {
        let mut rng = Rng::new(5);
        let spec = CorpusSpec {
            docs: 64,
            vocab: 1024,
            topics: 4,
            nnz_per_row: 30,
            topic_affinity: 0.9,
        };
        let (m, labels) = zipf_corpus(&spec, &mut rng);
        // Two docs of the same topic should overlap in terms far more
        // than docs of different topics.
        let overlap = |a: usize, b: usize| -> usize {
            let (ca, _) = m.row(a);
            let (cb, _) = m.row(b);
            ca.iter().filter(|c| cb.contains(c)).count()
        };
        assert_eq!(labels[0], labels[4]);
        assert_ne!(labels[0], labels[1]);
        let same: usize = (0..10).map(|i| overlap(4 * i, 4 * i + 4 * 5 % 60)).sum();
        let diff: usize = (0..10).map(|i| overlap(4 * i, 4 * i + 1)).sum();
        assert!(same > diff, "same-topic overlap {same} <= cross {diff}");
    }
}
