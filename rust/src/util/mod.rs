//! In-repo substrates: everything an offline build can't pull from
//! crates.io (see DESIGN.md §4). Each module is self-contained and
//! unit-tested.

pub mod argparse;
pub mod json;
pub mod memtrack;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod timer;
