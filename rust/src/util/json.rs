//! Minimal JSON parser (no serde available offline).
//!
//! Covers the subset needed by the artifact manifest and config files:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Parsing is strict: trailing garbage is an error.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// obj["key"] convenience accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize (used for experiment reports). Strings are escaped minimally.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"som_step": [{"name": "a", "s": 256, "d": 16.0,
                       "inputs": ["data", "mask"], "ok": true}],
                      "umatrix": []}"#;
        let j = Json::parse(src).unwrap();
        let steps = j.get("som_step").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(steps[0].get("s").unwrap().as_usize(), Some(256));
        assert_eq!(steps[0].get("d").unwrap().as_usize(), Some(16));
        assert_eq!(
            steps[0].get("inputs").unwrap().as_arr().unwrap()[1].as_str(),
            Some("mask")
        );
        assert_eq!(steps[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1F600}".to_string());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn numbers() {
        for (s, v) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap(), Json::Num(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_round_trip() {
        let src = r#"{"a":[1,2,{"b":null,"c":[true,false]}],"d":"x"}"#;
        let j = Json::parse(src).unwrap();
        let rt = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, rt);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → 🌍\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → 🌍"));
    }
}
