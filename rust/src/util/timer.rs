//! Timing helpers for the benchmark harness (no `criterion` offline).
//!
//! `bench` runs a closure with warmup + repeated timed iterations and
//! reports min/median/mean — the statistics the EXPERIMENTS.md tables
//! quote. Deliberately simple: the figures we reproduce compare
//! multi-second training runs, so micro-benchmark variance control
//! matters less than determinism.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort();
        let iters = samples.len();
        let min = samples[0];
        let max = samples[iters - 1];
        let median = samples[iters / 2];
        let total: Duration = samples.iter().sum();
        BenchStats {
            iters,
            min,
            median,
            mean: total / iters as u32,
            max,
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {:>10.3?}  median {:>10.3?}  mean {:>10.3?}  max {:>10.3?}  (n={})",
            self.min, self.median, self.mean, self.max, self.iters
        )
    }
}

/// Time one invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Run `f` `reps` times; return the last result and the BEST (minimum)
/// wall-clock in seconds. Minimum-of-N is the noise-robust estimator
/// the CI perf gates compare with: on shared runners a single
/// measurement is dominated by scheduler bursts, which only ever ADD
/// time — so best-observed is compared against best-observed.
pub fn best_secs<R>(reps: usize, mut f: impl FnMut() -> R) -> (R, f64) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let (r, t) = time_once(&mut f);
        best = best.min(t.as_secs_f64());
        out = Some(r);
    }
    (out.expect("reps >= 1"), best)
}

/// Run `f` `warmup` + `iters` times; return stats over the timed iters.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let samples: Vec<Duration> = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed()
        })
        .collect();
    BenchStats::from_samples(samples)
}

/// Benchmark-table row printer: aligned columns for the figure
/// reproductions ("series" = kernel name, "x" = sweep parameter).
pub fn print_row(series: &str, x: impl std::fmt::Display, stats: &BenchStats) {
    println!("{series:<24} {x:>12}  {stats}");
}

/// Scale factor for benches (SOM_BENCH_SCALE env: 0 < f <= 1; default
/// from the per-bench caller). Lets the full paper-sized sweeps run when
/// time allows and a fast CI pass otherwise.
pub fn bench_scale(default: f64) -> f64 {
    std::env::var("SOM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|f| *f > 0.0 && *f <= 100.0)
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = BenchStats::from_samples(vec![
            Duration::from_millis(5),
            Duration::from_millis(1),
            Duration::from_millis(3),
        ]);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.median, Duration::from_millis(3));
        assert_eq!(s.max, Duration::from_millis(5));
        assert_eq!(s.mean, Duration::from_millis(3));
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d < Duration::from_secs(1));
    }
}
