//! Allocation tracking — drives the paper's memory claims (Figs. 6–7,
//! §3.1 "minimum fifty per cent reduction in memory").
//!
//! A thin wrapper around the system allocator keeps live/peak byte
//! counters (two relaxed atomics per alloc — negligible next to the
//! training arithmetic). The library installs it as the global allocator
//! (see lib.rs), so every test/bench/example can snapshot memory regions
//! with `MemRegion`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

pub struct TrackingAlloc;

fn on_alloc(size: usize) {
    let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
    // Racy max update is fine: peaks are read at quiescent points.
    let mut peak = PEAK.load(Ordering::Relaxed);
    while live > peak {
        match PEAK.compare_exchange_weak(
            peak,
            live,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => break,
            Err(p) => peak = p,
        }
    }
}

fn on_dealloc(size: usize) {
    LIVE.fetch_sub(size, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            on_dealloc(layout.size());
            on_alloc(new_size);
        }
        p
    }
}

/// Currently live heap bytes (allocated through the global allocator).
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// High-water mark since process start (or last `reset_peak`).
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Reset the peak to the current live value (start of a measured region).
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Measurement scope: records the live baseline and the peak *increase*
/// over the region it covers.
pub struct MemRegion {
    baseline: usize,
}

impl MemRegion {
    pub fn start() -> Self {
        reset_peak();
        MemRegion {
            baseline: live_bytes(),
        }
    }

    /// Peak additional bytes allocated since `start()`.
    pub fn peak_delta(&self) -> usize {
        peak_bytes().saturating_sub(self.baseline)
    }

    /// Net live bytes still held since `start()`.
    pub fn live_delta(&self) -> usize {
        live_bytes().saturating_sub(self.baseline)
    }
}

// ---------------------------------------------------------------------
// Data-buffer + mapped-window gauges (out-of-core streaming, io::*).
//
// The allocator counters above see *everything*; the streaming claim in
// the paper ("memory use is highly optimized, enabling training large
// emergent maps even on a single computer") is specifically about the
// *training-data* working set. Each `DataSource` reports its resident
// buffer size here after every chunk, so benches and tests can assert
// peak data-buffer bytes stay O(chunk_rows * dim) instead of
// O(rows * dim), independent of codebook/accumulator allocations.
//
// Two gauges, same mechanics:
//
// * data buffer — heap bytes a source *owns* (chunk Vecs, scratch CSRs,
//   prefetch transit buffers). These go through the global allocator.
// * mapped window — bytes of a memory-mapped file (`io::mmap`) a source
//   is currently handing to the kernel as a borrowed chunk view. The
//   allocator never sees them (they live in the OS page cache), so
//   they need their own ledger for the bounded-memory assertions: a
//   zero-copy source must report O(chunk) mapped-view bytes, not the
//   whole file, to claim the same working-set bound.

/// Additive live/peak byte ledger shared by the streaming gauges.
struct Gauge {
    live: AtomicUsize,
    peak: AtomicUsize,
}

impl Gauge {
    const fn new() -> Self {
        Gauge {
            live: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// One reporter's share changed from `old_bytes` to `new_bytes`.
    fn resize(&self, old_bytes: usize, new_bytes: usize) {
        let live = if new_bytes >= old_bytes {
            let d = new_bytes - old_bytes;
            self.live.fetch_add(d, Ordering::Relaxed) + d
        } else {
            let d = old_bytes - new_bytes;
            self.live.fetch_sub(d, Ordering::Relaxed).saturating_sub(d)
        };
        let mut peak = self.peak.load(Ordering::Relaxed);
        while live > peak {
            match self.peak.compare_exchange_weak(
                peak,
                live,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    fn live(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    fn reset_peak(&self) {
        self.peak
            .store(self.live.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

static DATA_BUF: Gauge = Gauge::new();
static DATA_MAP: Gauge = Gauge::new();

/// Adjust the gauge for one source whose resident buffer changed from
/// `old_bytes` to `new_bytes`. The gauge is *additive* across sources
/// (each cluster rank's source contributes its own share), so callers
/// must pass their previous report as `old_bytes` and release with
/// `(reported, 0)` when dropped — the `DataSource` implementations do
/// both.
pub fn data_buffer_resize(old_bytes: usize, new_bytes: usize) {
    DATA_BUF.resize(old_bytes, new_bytes);
}

/// Currently resident data-buffer bytes, summed over live sources.
pub fn data_buffer_bytes() -> usize {
    DATA_BUF.live()
}

/// High-water mark of resident data-buffer bytes since the last reset.
pub fn data_buffer_peak() -> usize {
    DATA_BUF.peak()
}

/// Start a fresh data-buffer measurement region: the peak restarts from
/// the currently live total (sources may still be alive).
pub fn reset_data_buffer_peak() {
    DATA_BUF.reset_peak();
}

/// Adjust the mapped-window gauge for one zero-copy source whose
/// currently exposed chunk view changed from `old_bytes` to `new_bytes`
/// of mapped file. Same additive contract as [`data_buffer_resize`]:
/// report deltas against your previous share, release with
/// `(reported, 0)` on drop.
pub fn data_map_resize(old_bytes: usize, new_bytes: usize) {
    DATA_MAP.resize(old_bytes, new_bytes);
}

/// Mapped-file bytes currently exposed as chunk views, over live sources.
pub fn data_map_bytes() -> usize {
    DATA_MAP.live()
}

/// High-water mark of exposed mapped-window bytes since the last reset.
pub fn data_map_peak() -> usize {
    DATA_MAP.peak()
}

/// Start a fresh mapped-window measurement region.
pub fn reset_data_map_peak() {
    DATA_MAP.reset_peak();
}

/// Pretty-printer for byte counts in reports.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_alloc_and_free() {
        let region = MemRegion::start();
        let v: Vec<u8> = Vec::with_capacity(1 << 20);
        assert!(region.peak_delta() >= 1 << 20, "{}", region.peak_delta());
        drop(v);
        // live returns to (near) baseline; other test threads may be
        // allocating concurrently, so allow slack.
        assert!(region.live_delta() < 1 << 19);
    }

    #[test]
    fn peak_survives_free() {
        let region = MemRegion::start();
        {
            let _v: Vec<u64> = vec![0; 1 << 18]; // 2 MiB
        }
        assert!(region.peak_delta() >= (1 << 18) * 8);
    }

    #[test]
    fn data_buffer_gauge_tracks_peak() {
        // The gauge is global and other tests in this binary may adjust
        // concurrently, so assert only monotone facts.
        data_buffer_resize(0, 4096);
        assert!(data_buffer_peak() >= 4096);
        data_buffer_resize(4096, 512); // shrink this source's buffer
        data_buffer_resize(512, 0); // drop it
        assert!(data_buffer_peak() >= 4096); // peak survives release
    }

    #[test]
    fn mapped_window_gauge_tracks_peak() {
        // Separate ledger from the data-buffer gauge: mapped views never
        // pass through the allocator, so they must not leak into (or
        // read from) the heap gauge.
        let buf_before = data_buffer_peak();
        data_map_resize(0, 1 << 20);
        assert!(data_map_peak() >= 1 << 20);
        data_map_resize(1 << 20, 0);
        assert!(data_map_peak() >= 1 << 20); // peak survives release
        // The 1 MiB map report must not have leaked into the heap gauge
        // (other lib tests run concurrently and report small buffers, so
        // allow slack well below the 1 MiB signal).
        assert!(data_buffer_peak() <= buf_before + 512 * 1024);
    }

    #[test]
    fn fmt_bytes_readable() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
