//! Scoped fork-join parallelism — the OpenMP substitute.
//!
//! The paper's single-node design point (§3.1) is that OpenMP threads
//! share one codebook instead of MPI processes each holding a copy,
//! halving memory. `parallel_chunks` reproduces that shape: worker
//! threads borrow disjoint chunks of the input and a shared read-only
//! view of the codebook; per-thread partial accumulators are merged by
//! the caller (the OpenMP reduction clause).
//!
//! Implemented on `std::thread::scope` — no pool object needs to persist,
//! and for epoch-granularity work the spawn cost (~10 µs/thread) is
//! irrelevant; the hot loops run inside the workers.

/// Number of worker threads to use: SOMOCLU_THREADS env var, else
/// available_parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SOMOCLU_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `total` items into at most `parts` contiguous ranges of
/// near-equal size (first `total % parts` ranges get one extra).
pub fn split_ranges(total: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(total.max(1));
    let base = total / parts;
    let extra = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Minimum items per worker range: below this, the ~10 µs/thread spawn
/// dominates the work itself. Streaming chunks can be tiny (the last
/// chunk of a pass, or a small `--chunk-rows`), and before this floor a
/// 16-row chunk on a 16-core host paid 16 spawns for ~one row each.
///
/// Calibration: 64, not higher — parallel_ranges also carries the BMU
/// search, where one item costs O(nodes · dim) (~10 µs/row on a 50×50
/// map at dim 32, so 64 items already amortize a spawn ~60×). A 256
/// floor would cap the README-recommended `--chunk-rows 1000` at 4
/// threads and sink the streaming-vs-resident acceptance target; at 64
/// that chunk still fans out to 16 threads.
///
/// Results are unaffected by construction: BMUs are per-row and the
/// accumulation is node-parallel, so thread count never changes output
/// (see `thread_count_invariant`).
pub const MIN_ITEMS_PER_THREAD: usize = 64;

/// Fork-join map over contiguous index ranges: `f(thread_idx, range)` runs
/// on its own thread; the Vec of results is returned in range order.
/// The thread count is capped so each range carries at least
/// [`MIN_ITEMS_PER_THREAD`] items (tiny inputs run inline on the caller).
///
/// `f` only borrows (scoped threads), so callers can close over shared
/// slices — this is exactly the "threads share one codebook" memory model
/// the paper credits for the ≥50% reduction.
pub fn parallel_ranges<T, F>(total: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.min(total.div_ceil(MIN_ITEMS_PER_THREAD)).max(1);
    let ranges = split_ranges(total, threads);
    if ranges.len() <= 1 {
        return ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| f(i, r))
            .collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                let f = &f;
                scope.spawn(move || f(i, r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Run `n` closures concurrently and collect results in order (used by
/// the simulated cluster to run one task per rank).
pub fn run_concurrent<T, F>(tasks: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = tasks
            .into_iter()
            .map(|t| scope.spawn(t))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_covers_everything_once() {
        for total in [0usize, 1, 7, 100, 101, 1024] {
            for parts in [1usize, 2, 3, 8, 200] {
                let ranges = split_ranges(total, parts);
                let mut covered = vec![false; total];
                for r in &ranges {
                    for i in r.clone() {
                        assert!(!covered[i]);
                        covered[i] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "{total}/{parts}");
                // Near-equal: sizes differ by at most 1.
                let sizes: Vec<_> = ranges.iter().map(|r| r.len()).collect();
                let (mn, mx) = (
                    sizes.iter().min().unwrap(),
                    sizes.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1);
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<u64> = (0..10_000).collect();
        let partials = parallel_ranges(data.len(), 4, |_, r| {
            data[r].iter().sum::<u64>()
        });
        assert_eq!(partials.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn runs_in_range_order() {
        let got = parallel_ranges(100, 5, |i, r| (i, r.start));
        for w in got.windows(2) {
            assert!(w[0].1 < w[1].1);
        }
    }

    #[test]
    fn concurrent_tasks_all_run() {
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..8)
            .map(|i| {
                let c = &counter;
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i * 2
                }
            })
            .collect();
        let out = run_concurrent(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        assert_eq!(out, (0..8).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_ranges(10, 1, |i, r| (i, r));
        assert_eq!(out, vec![(0, 0..10)]);
    }

    #[test]
    fn min_items_floor_caps_range_count() {
        // Tiny totals collapse to few ranges regardless of the requested
        // thread count; totals that give every thread at least the floor
        // still honor the requested count.
        assert_eq!(parallel_ranges(10, 8, |i, r| (i, r)).len(), 1);
        assert_eq!(parallel_ranges(MIN_ITEMS_PER_THREAD, 8, |i, r| (i, r)).len(), 1);
        assert_eq!(
            parallel_ranges(2 * MIN_ITEMS_PER_THREAD, 8, |i, r| (i, r)).len(),
            2
        );
        // A 1000-row streaming chunk keeps full 8-way parallelism.
        assert_eq!(parallel_ranges(1000, 8, |i, r| (i, r)).len(), 8);
        assert_eq!(
            parallel_ranges(MIN_ITEMS_PER_THREAD * 8, 8, |i, r| (i, r)).len(),
            8
        );
        // Coverage is unchanged by the floor.
        for total in [10usize, 100, 1000] {
            let got = parallel_ranges(total, 8, |_, r| r);
            let sum: usize = got.iter().map(|r| r.len()).sum();
            assert_eq!(sum, total);
        }
    }
}
