//! Mini property-testing harness (no `proptest` available offline).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! re-runs with progressively "smaller" cases drawn from the same
//! generator (size-bounded regeneration shrinking — not structural
//! shrinking, but enough to report a small counterexample) and panics
//! with the failing seed so the case can be replayed exactly.

use super::rng::Rng;

/// Generation context: wraps the RNG with a size bound that the shrink
/// loop tightens.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size.max(1));
        if hi <= lo {
            return lo;
        }
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            seed: 0x50_4f_50_54, // "POPT"
            max_size: 64,
        }
    }
}

/// Check `prop` over `cfg.cases` random cases. `prop` returns
/// `Err(description)` to signal failure.
pub fn check_with<F>(cfg: Config, name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            size,
        };
        if let Err(msg) = prop(&mut g) {
            // Shrink: re-generate with smaller sizes from nearby seeds and
            // keep the smallest failure found.
            let mut best = (size, case_seed, msg);
            for shrink_size in (1..size).rev() {
                let mut found = false;
                for probe in 0..20u64 {
                    let s = case_seed ^ probe.wrapping_mul(0xd1342543de82ef95);
                    let mut g = Gen {
                        rng: Rng::new(s),
                        size: shrink_size,
                    };
                    if let Err(m) = prop(&mut g) {
                        best = (shrink_size, s, m);
                        found = true;
                        break;
                    }
                }
                if !found {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}): {}\n  \
                 minimal size={} seed={:#x} — replay with Gen{{Rng::new(seed), size}}",
                best.2, best.0, best.1
            );
        }
    }
}

/// Check with default config.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_with(Config::default(), name, prop)
}

/// Helper macro for property assertions inside `check` closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", |g| {
            let a = g.f32_in(-100.0, 100.0);
            let b = g.f32_in(-100.0, 100.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |_| Err("nope".into()));
    }

    #[test]
    fn size_grows_over_cases() {
        // Early cases should be small: verify usize_in respects size.
        let mut g = Gen {
            rng: Rng::new(1),
            size: 3,
        };
        for _ in 0..100 {
            assert!(g.usize_in(0, 1000) <= 3);
        }
    }

    #[test]
    fn prop_assert_macro() {
        check("macro", |g| {
            let n = g.usize_in(0, 10);
            prop_assert!(n <= 10, "n = {n}");
            Ok(())
        });
    }
}
