//! Getopt-style CLI parser (no `clap` available offline).
//!
//! Mirrors the classic somoclu command line: short flags with values
//! (`-e 10`), long aliases (`--rows 20`), positional arguments, and a
//! generated usage text. Only what the somoclu CLI needs — not a general
//! library.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub short: Option<char>,
    pub long: Option<&'static str>,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct ArgSpec {
    opts: Vec<(&'static str, OptSpec)>, // name -> spec (ordered for usage)
    positionals: Vec<(&'static str, &'static str)>, // name, help
}

#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positionals: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    #[error("unknown option: {0}")]
    Unknown(String),
    #[error("option {0} requires a value")]
    MissingValue(String),
    #[error("missing required positional argument <{0}>")]
    MissingPositional(&'static str),
    #[error("unexpected extra argument: {0}")]
    Extra(String),
    #[error("invalid value for {opt}: {val}: {why}")]
    BadValue {
        opt: String,
        val: String,
        why: String,
    },
}

impl ArgSpec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn opt(
        mut self,
        name: &'static str,
        short: Option<char>,
        long: Option<&'static str>,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push((
            name,
            OptSpec {
                short,
                long,
                takes_value: true,
                help,
                default,
            },
        ));
        self
    }

    pub fn flag(
        mut self,
        name: &'static str,
        short: Option<char>,
        long: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        self.opts.push((
            name,
            OptSpec {
                short,
                long,
                takes_value: false,
                help,
                default: None,
            },
        ));
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    fn find_short(&self, c: char) -> Option<(&'static str, &OptSpec)> {
        self.opts
            .iter()
            .find(|(_, s)| s.short == Some(c))
            .map(|(n, s)| (*n, s))
    }

    fn find_long(&self, l: &str) -> Option<(&'static str, &OptSpec)> {
        self.opts
            .iter()
            .find(|(_, s)| s.long == Some(l))
            .map(|(n, s)| (*n, s))
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut out = format!("Usage: {prog} [OPTIONS]");
        for (name, _) in &self.positionals {
            out.push_str(&format!(" {name}"));
        }
        out.push_str("\n\nOptions:\n");
        for (_, spec) in &self.opts {
            let mut line = String::from("  ");
            if let Some(c) = spec.short {
                line.push_str(&format!("-{c}"));
            }
            if let Some(l) = spec.long {
                if spec.short.is_some() {
                    line.push_str(", ");
                }
                line.push_str(&format!("--{l}"));
            }
            if spec.takes_value {
                line.push_str(" VALUE");
            }
            while line.len() < 28 {
                line.push(' ');
            }
            line.push_str(spec.help);
            if let Some(d) = spec.default {
                line.push_str(&format!(" [default: {d}]"));
            }
            out.push_str(&line);
            out.push('\n');
        }
        for (name, help) in &self.positionals {
            out.push_str(&format!("  {name:<26}{help}\n"));
        }
        out
    }

    pub fn parse<I, S>(&self, args: I) -> Result<Parsed, ArgError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        for (name, spec) in &self.opts {
            if let Some(d) = spec.default {
                values.insert(*name, d.to_string());
            }
            if !spec.takes_value {
                flags.insert(*name, false);
            }
        }

        let mut it = args.into_iter().map(Into::into).peekable();
        while let Some(arg) = it.next() {
            if let Some(long) = arg.strip_prefix("--") {
                // --opt=value or --opt value
                let (key, inline) = match long.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (long, None),
                };
                let (name, spec) = self
                    .find_long(key)
                    .ok_or_else(|| ArgError::Unknown(arg.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| ArgError::MissingValue(arg.clone()))?,
                    };
                    values.insert(name, v);
                } else {
                    flags.insert(name, true);
                }
            } else if arg.len() >= 2 && arg.starts_with('-') && !is_number(&arg) {
                let c = arg.chars().nth(1).unwrap();
                let (name, spec) = self
                    .find_short(c)
                    .ok_or_else(|| ArgError::Unknown(arg.clone()))?;
                if spec.takes_value {
                    // -eVALUE or -e VALUE
                    let rest = &arg[2..];
                    let v = if !rest.is_empty() {
                        rest.to_string()
                    } else {
                        it.next()
                            .ok_or_else(|| ArgError::MissingValue(arg.clone()))?
                    };
                    values.insert(name, v);
                } else {
                    flags.insert(name, true);
                }
            } else {
                positionals.push(arg);
            }
        }

        if positionals.len() > self.positionals.len() {
            return Err(ArgError::Extra(
                positionals[self.positionals.len()].clone(),
            ));
        }
        if positionals.len() < self.positionals.len() {
            return Err(ArgError::MissingPositional(
                self.positionals[positionals.len()].0,
            ));
        }
        Ok(Parsed {
            values,
            flags,
            positionals,
        })
    }
}

fn is_number(s: &str) -> bool {
    s[1..].chars().next().is_some_and(|c| c.is_ascii_digit())
        && s[1..].parse::<f64>().is_ok()
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self, idx: usize) -> &str {
        &self.positionals[idx]
    }

    pub fn parse_as<T: std::str::FromStr>(
        &self,
        name: &'static str,
    ) -> Result<T, ArgError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name).ok_or(ArgError::MissingValue(
            name.to_string(),
        ))?;
        raw.parse::<T>().map_err(|e| ArgError::BadValue {
            opt: name.to_string(),
            val: raw.to_string(),
            why: e.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new()
            .opt("epochs", Some('e'), Some("epochs"), "training epochs", Some("10"))
            .opt("rows", Some('y'), Some("rows"), "map rows", Some("50"))
            .flag("verbose", Some('v'), Some("verbose"), "chatty output")
            .positional("INPUT", "input file")
            .positional("OUTPUT", "output prefix")
    }

    #[test]
    fn parses_mixed_styles() {
        let p = spec()
            .parse(["-e", "20", "--rows=30", "-v", "in.txt", "out"])
            .unwrap();
        assert_eq!(p.parse_as::<u32>("epochs").unwrap(), 20);
        assert_eq!(p.parse_as::<u32>("rows").unwrap(), 30);
        assert!(p.flag("verbose"));
        assert_eq!(p.positional(0), "in.txt");
        assert_eq!(p.positional(1), "out");
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(["a", "b"]).unwrap();
        assert_eq!(p.parse_as::<u32>("epochs").unwrap(), 10);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn attached_short_value() {
        let p = spec().parse(["-e20", "a", "b"]).unwrap();
        assert_eq!(p.parse_as::<u32>("epochs").unwrap(), 20);
    }

    #[test]
    fn long_space_separated() {
        let p = spec().parse(["--epochs", "7", "a", "b"]).unwrap();
        assert_eq!(p.parse_as::<u32>("epochs").unwrap(), 7);
    }

    #[test]
    fn errors() {
        assert!(matches!(
            spec().parse(["-q", "a", "b"]),
            Err(ArgError::Unknown(_))
        ));
        assert!(matches!(
            spec().parse(["a", "b", "c"]),
            Err(ArgError::Extra(_))
        ));
        assert!(matches!(spec().parse(["a"]), Err(ArgError::MissingPositional(_))));
        assert!(matches!(
            spec().parse(["--epochs"]),
            Err(ArgError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_value_reports_option() {
        let p = spec().parse(["-e", "abc", "a", "b"]).unwrap();
        match p.parse_as::<u32>("epochs") {
            Err(ArgError::BadValue { opt, .. }) => assert_eq!(opt, "epochs"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn negative_number_positional_not_an_option() {
        let s = ArgSpec::new().positional("X", "x");
        let p = s.parse(["-3.5"]).unwrap();
        assert_eq!(p.positional(0), "-3.5");
    }

    #[test]
    fn usage_mentions_everything() {
        let u = spec().usage("somoclu");
        for needle in ["--epochs", "-v", "INPUT", "OUTPUT", "default: 10"] {
            assert!(u.contains(needle), "{u}");
        }
    }
}
