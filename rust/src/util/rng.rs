//! Deterministic, seedable PRNG: xoshiro256++ plus distribution samplers.
//!
//! Built in-repo because no `rand` crate is available offline; the
//! generator is the reference xoshiro256++ by Blackman & Vigna (public
//! domain), which is more than adequate for synthetic workload generation
//! and codebook initialization. Determinism matters: every experiment in
//! EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64, used to seed xoshiro from a single u64 (reference method).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is invalid for xoshiro; splitmix can only produce
        // it with negligible probability, but guard anyway.
        if s == [0, 0, 0, 0] {
            Self::new(seed ^ 0xdeadbeefcafef00d)
        } else {
            Rng { s }
        }
    }

    /// Derive an independent stream (e.g. one per worker rank).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> exactly representable uniform grid.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is not critical at data-gen time).
    pub fn normal_f32(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Zipf-distributed rank in [0, n): P(k) ∝ 1/(k+1)^exponent.
    /// Inverse-CDF over a precomputed table is the caller's job for bulk
    /// sampling (see data::text); this is the simple rejection variant
    /// kept for small n.
    pub fn zipf(&mut self, n: usize, exponent: f64) -> usize {
        debug_assert!(n > 0);
        // Rejection sampling (Devroye) adapted for bounded n.
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = ((n as f64).powf(1.0 - exponent).mul_add(u, 1.0 - u))
                .powf(1.0 / (1.0 - exponent));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                let ratio = (k as f64 / x).powf(exponent);
                if v * x / k as f64 <= ratio {
                    return k - 1;
                }
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let k = r.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sum2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal_f32() as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(5);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            let k = r.zipf(n, 1.2);
            assert!(k < n);
            counts[k] += 1;
        }
        // Rank 0 must dominate deep ranks by a wide margin.
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(77);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
