//! Single-core online-SOM baseline — the R `kohonen` comparator of
//! Fig. 5.
//!
//! The kohonen package trains the *online* (per-sample) formulation on a
//! single core, updating every map node after each presented instance —
//! exactly the work profile our Fig. 5 harness needs to compare against:
//! "Compared to the R package, even the CPU version is at least ten times
//! faster." Deliberately unthreaded and unblocked; do not optimize.

use crate::som::{Codebook, Grid, Neighborhood, Schedule};

/// Result of a baseline run.
pub struct BaselineResult {
    pub codebook: Codebook,
    pub bmus: Vec<u32>,
    pub qe_history: Vec<f64>,
}

/// kohonen-style init: sample codebook vectors from the data. Like the
/// package, it *refuses* emergent maps ("if the map has more nodes than
/// data instances, kohonen exits with an error message") — faithfully
/// reproduced so the Fig. 5 harness can show the same limitation.
pub fn kohonen_like_init(
    grid: &Grid,
    data: &[f32],
    dim: usize,
    rng: &mut crate::util::rng::Rng,
) -> Result<Codebook, String> {
    let rows = data.len() / dim;
    let nodes = grid.node_count();
    if nodes > rows {
        return Err(format!(
            "kohonen-like baseline cannot initialize {nodes} nodes from \
             {rows} instances (emergent maps unsupported, like the R package)"
        ));
    }
    Ok(Codebook::sample_init(nodes, dim, data, rows, rng))
}

/// Train with the online rule (Eq. 4): for each instance, find the BMU
/// (plain non-Gram distance loop), then update *every* node's weights.
pub fn train_online(
    grid: &Grid,
    mut codebook: Codebook,
    data: &[f32],
    dim: usize,
    epochs: usize,
    radius: Schedule,
    alpha: Schedule,
    neighborhood: Neighborhood,
) -> BaselineResult {
    let rows = data.len() / dim;
    assert_eq!(codebook.dim, dim);
    let mut bmus = vec![0u32; rows];
    let mut qe_history = Vec::with_capacity(epochs);

    for epoch in 0..epochs {
        let r = radius.at(epoch);
        let a = alpha.at(epoch);
        let mut qe_sum = 0.0f64;
        for row in 0..rows {
            let x = &data[row * dim..(row + 1) * dim];
            // BMU search without the Gram trick — the naive profile.
            let (mut best, mut best_d) = (0usize, f32::INFINITY);
            for n in 0..codebook.nodes {
                let mut d2 = 0.0f32;
                for (xi, wi) in x.iter().zip(codebook.row(n)) {
                    let d = xi - wi;
                    d2 += d * d;
                }
                if d2 < best_d {
                    best_d = d2;
                    best = n;
                }
            }
            bmus[row] = best as u32;
            qe_sum += (best_d as f64).sqrt();
            // Online update of every node (the unthresholded full-map
            // sweep that makes the package slow).
            for n in 0..codebook.nodes {
                let h = neighborhood.weight(grid.distance(best, n), r);
                if h > 0.0 {
                    let w = codebook.row_mut(n);
                    for (wi, xi) in w.iter_mut().zip(x) {
                        *wi += a * h * (xi - *wi);
                    }
                }
            }
        }
        qe_history.push(qe_sum / rows as f64);
    }

    BaselineResult {
        codebook,
        bmus,
        qe_history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::{Cooling, GridType, MapType};
    use crate::util::rng::Rng;

    #[test]
    fn online_converges_on_blobs() {
        let mut rng = Rng::new(21);
        let (data, _) = crate::data::gaussian_blobs(120, 4, 3, 0.1, &mut rng);
        let grid = Grid::new(5, 5, GridType::Square, MapType::Planar);
        let cb = kohonen_like_init(&grid, &data, 4, &mut rng).unwrap();
        let res = train_online(
            &grid,
            cb,
            &data,
            4,
            8,
            Schedule::new(2.5, 0.5, Cooling::Linear, 8),
            Schedule::new(0.5, 0.02, Cooling::Linear, 8),
            Neighborhood::gaussian(false),
        );
        assert!(
            res.qe_history.last().unwrap() < &(res.qe_history[0] * 0.8),
            "{:?}",
            res.qe_history
        );
    }

    #[test]
    fn refuses_emergent_maps_like_kohonen() {
        let mut rng = Rng::new(22);
        let grid = Grid::new(20, 20, GridType::Square, MapType::Planar);
        let data = vec![0.0f32; 10 * 4]; // 10 rows < 400 nodes
        assert!(kohonen_like_init(&grid, &data, 4, &mut rng).is_err());
    }
}
