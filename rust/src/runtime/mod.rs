//! PJRT runtime: load AOT HLO-text artifacts once, execute them from the
//! training hot path (the paper's CUDA runtime, replaced by XLA/PJRT).
//!
//! The interchange format is HLO *text*: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). Executables are
//! compiled on first use and cached for the life of the engine; constant
//! inputs (grid coords, node validity, span) live on-device across the
//! whole run so the per-epoch upload is just codebook + data shards.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;

pub use manifest::{Manifest, SomStepArtifact};

/// Lazily-compiled executable cache over a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exe_cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create an engine over `artifacts_dir` (compiles nothing yet).
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            exe_cache: HashMap::new(),
        })
    }

    /// Engine over the default artifact dir (SOMOCLU_ARTIFACTS env or
    /// ./artifacts).
    pub fn from_env() -> anyhow::Result<Self> {
        Self::new(Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by file name (cached).
    pub fn executable(&mut self, file: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.exe_cache.contains_key(file) {
            let path = self.manifest.artifact_path(file);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                anyhow::anyhow!("loading HLO text {}: {e}", path.display())
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.exe_cache.insert(file.to_string(), exe);
        }
        Ok(&self.exe_cache[file])
    }

    /// Host f32 slice -> device buffer.
    pub fn to_device_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Host i32 slice -> device buffer.
    pub fn to_device_i32(
        &self,
        data: &[i32],
        dims: &[usize],
    ) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// U-matrix through the AOT `umatrix_*` artifact (Eq. 7 on the
/// accelerator) — the accel-path counterpart of `som::umatrix::umatrix`.
pub fn umatrix_accel(
    engine: &mut Engine,
    grid: &crate::som::Grid,
    codebook: &crate::som::Codebook,
) -> anyhow::Result<Vec<f32>> {
    let nodes = codebook.nodes;
    let dim = codebook.dim;
    anyhow::ensure!(grid.node_count() == nodes, "grid/codebook mismatch");
    let art = engine
        .manifest()
        .umatrix
        .iter()
        .filter(|a| a.d >= dim && a.n >= nodes && a.k >= 8)
        .min_by_key(|a| a.n * a.d)
        .ok_or_else(|| anyhow::anyhow!("no umatrix artifact fits n={nodes} d={dim}"))?
        .clone();

    // Pad codebook, neighbor tables and validity to the artifact shape.
    let mut cb = vec![0.0f32; art.n * art.d];
    for node in 0..nodes {
        cb[node * art.d..node * art.d + dim].copy_from_slice(codebook.row(node));
    }
    let (idx_small, mask_small) = crate::som::umatrix::neighbor_tables(grid, art.k);
    let mut idx = vec![0i32; art.n * art.k];
    let mut mask = vec![0.0f32; art.n * art.k];
    idx[..nodes * art.k].copy_from_slice(&idx_small);
    mask[..nodes * art.k].copy_from_slice(&mask_small);
    let mut valid = vec![1.0f32; nodes];
    valid.resize(art.n, 0.0);

    let cb_buf = engine.to_device_f32(&cb, &[art.n, art.d])?;
    let idx_buf = engine.to_device_i32(&idx, &[art.n, art.k])?;
    let mask_buf = engine.to_device_f32(&mask, &[art.n, art.k])?;
    let valid_buf = engine.to_device_f32(&valid, &[art.n])?;
    let exe = engine.executable(&art.file)?;
    let parts = untuple(exe.execute_b(&[&cb_buf, &idx_buf, &mask_buf, &valid_buf])?)?;
    anyhow::ensure!(parts.len() == 1, "expected 1 output");
    let mut u = parts[0].to_vec::<f32>()?;
    u.truncate(nodes);
    Ok(u)
}

/// Decompose a single-tuple execution result into element literals.
pub fn untuple(result: Vec<Vec<xla::PjRtBuffer>>) -> anyhow::Result<Vec<xla::Literal>> {
    let buf = result
        .into_iter()
        .next()
        .and_then(|replica| replica.into_iter().next())
        .ok_or_else(|| anyhow::anyhow!("execution produced no output buffer"))?;
    let lit = buf.to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn engine_loads_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Engine::from_env().unwrap();
        assert_eq!(engine.platform_name(), "cpu");
        assert!(!engine.manifest().som_steps.is_empty());
    }

    #[test]
    fn compile_and_cache_tiny_artifact() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::from_env().unwrap();
        let file = engine
            .manifest()
            .select_som_step("gaussian", "planar", 16, 256)
            .unwrap()
            .file
            .clone();
        engine.executable(&file).unwrap();
        assert_eq!(engine.exe_cache.len(), 1);
        engine.executable(&file).unwrap(); // cached, no recompile
        assert_eq!(engine.exe_cache.len(), 1);
    }
}
