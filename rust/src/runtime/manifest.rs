//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Parsed with the in-repo JSON parser.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT-lowered `som_epoch_step` variant.
#[derive(Clone, Debug)]
pub struct SomStepArtifact {
    pub name: String,
    pub file: String,
    pub shape: String,
    /// Neighborhood variant: gaussian | gaussian_compact | bubble.
    pub kind: String,
    /// planar | toroid.
    pub map_type: String,
    /// Shard row capacity.
    pub s: usize,
    /// Feature-dim capacity.
    pub d: usize,
    /// Node capacity.
    pub n: usize,
    pub block_s: usize,
    pub block_n: usize,
}

/// One AOT-lowered BMU-only artifact (hybrid kernel / ablation bench).
#[derive(Clone, Debug)]
pub struct BmuArtifact {
    pub name: String,
    pub file: String,
    pub shape: String,
    /// "gram" (the paper's chosen formulation) or "direct" (the naive
    /// design the paper benchmarked against and rejected).
    pub variant: String,
    pub s: usize,
    pub d: usize,
    pub n: usize,
    pub block_s: usize,
    pub block_n: usize,
}

/// One AOT-lowered `umatrix_step` artifact.
#[derive(Clone, Debug)]
pub struct UmatrixArtifact {
    pub name: String,
    pub file: String,
    pub shape: String,
    pub n: usize,
    pub k: usize,
    pub d: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub som_steps: Vec<SomStepArtifact>,
    pub umatrix: Vec<UmatrixArtifact>,
    pub bmu: Vec<BmuArtifact>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("cannot read manifest {path}: {source}")]
    Io {
        path: String,
        source: std::io::Error,
    },
    #[error("manifest parse error: {0}")]
    Parse(String),
    #[error(
        "no artifact fits request: kind={kind} map={map_type} dim<= {d} nodes<= {n} \
         (available: {available}) — re-run `make artifacts` with a config that covers it"
    )]
    NoFit {
        kind: String,
        map_type: String,
        d: usize,
        n: usize,
        available: String,
    },
}

fn req_str(j: &Json, key: &str) -> Result<String, ManifestError> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| ManifestError::Parse(format!("missing string field {key}")))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, ManifestError> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| ManifestError::Parse(format!("missing numeric field {key}")))
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|source| ManifestError::Io {
            path: path.display().to_string(),
            source,
        })?;
        let j = Json::parse(&text).map_err(|e| ManifestError::Parse(e.to_string()))?;

        let mut som_steps = Vec::new();
        for entry in j
            .get("som_step")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("missing som_step array".into()))?
        {
            som_steps.push(SomStepArtifact {
                name: req_str(entry, "name")?,
                file: req_str(entry, "file")?,
                shape: req_str(entry, "shape")?,
                kind: req_str(entry, "kind")?,
                map_type: req_str(entry, "map_type")?,
                s: req_usize(entry, "s")?,
                d: req_usize(entry, "d")?,
                n: req_usize(entry, "n")?,
                block_s: req_usize(entry, "block_s")?,
                block_n: req_usize(entry, "block_n")?,
            });
        }
        let mut bmu = Vec::new();
        if let Some(arr) = j.get("bmu").and_then(Json::as_arr) {
            for entry in arr {
                bmu.push(BmuArtifact {
                    name: req_str(entry, "name")?,
                    file: req_str(entry, "file")?,
                    shape: req_str(entry, "shape")?,
                    variant: req_str(entry, "variant")?,
                    s: req_usize(entry, "s")?,
                    d: req_usize(entry, "d")?,
                    n: req_usize(entry, "n")?,
                    block_s: req_usize(entry, "block_s")?,
                    block_n: req_usize(entry, "block_n")?,
                });
            }
        }
        let mut umatrix = Vec::new();
        for entry in j
            .get("umatrix")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("missing umatrix array".into()))?
        {
            umatrix.push(UmatrixArtifact {
                name: req_str(entry, "name")?,
                file: req_str(entry, "file")?,
                shape: req_str(entry, "shape")?,
                n: req_usize(entry, "n")?,
                k: req_usize(entry, "k")?,
                d: req_usize(entry, "d")?,
            });
        }
        Ok(Manifest {
            dir,
            som_steps,
            umatrix,
            bmu,
        })
    }

    /// Pick the smallest-capacity BMU-only artifact that fits.
    pub fn select_bmu(
        &self,
        variant: &str,
        dim: usize,
        nodes: usize,
    ) -> Result<&BmuArtifact, ManifestError> {
        self.bmu
            .iter()
            .filter(|a| a.variant == variant && a.d >= dim && a.n >= nodes)
            .min_by_key(|a| a.s * a.d * a.n)
            .ok_or_else(|| ManifestError::NoFit {
                kind: format!("bmu/{variant}"),
                map_type: "-".into(),
                d: dim,
                n: nodes,
                available: self
                    .bmu
                    .iter()
                    .map(|a| format!("{}/{}(d{},n{})", a.shape, a.variant, a.d, a.n))
                    .collect::<Vec<_>>()
                    .join(", "),
            })
    }

    /// Pick the smallest-capacity som_step artifact that fits
    /// (kind + map type exact; d and n padded up). Minimizes padded FLOPs
    /// = s * d * n.
    pub fn select_som_step(
        &self,
        kind: &str,
        map_type: &str,
        dim: usize,
        nodes: usize,
    ) -> Result<&SomStepArtifact, ManifestError> {
        self.som_steps
            .iter()
            .filter(|a| {
                a.kind == kind && a.map_type == map_type && a.d >= dim && a.n >= nodes
            })
            .min_by_key(|a| a.s * a.d * a.n)
            .ok_or_else(|| ManifestError::NoFit {
                kind: kind.into(),
                map_type: map_type.into(),
                d: dim,
                n: nodes,
                available: self
                    .som_steps
                    .iter()
                    .map(|a| format!("{}(d{},n{})", a.shape, a.d, a.n))
                    .collect::<Vec<_>>()
                    .join(", "),
            })
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Default artifact directory: SOMOCLU_ARTIFACTS env or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("SOMOCLU_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Manifest {
        let mk = |shape: &str, kind: &str, map: &str, s, d, n| SomStepArtifact {
            name: format!("som_step_{shape}_{kind}_{map}"),
            file: format!("som_step_{shape}_{kind}_{map}.hlo.txt"),
            shape: shape.into(),
            kind: kind.into(),
            map_type: map.into(),
            s,
            d,
            n,
            block_s: 64,
            block_n: 64,
        };
        Manifest {
            dir: PathBuf::from("/nonexistent"),
            som_steps: vec![
                mk("tiny", "gaussian", "planar", 256, 16, 256),
                mk("medium", "gaussian", "planar", 1024, 256, 2560),
                mk("tiny", "bubble", "planar", 256, 16, 256),
            ],
            umatrix: vec![],
            bmu: vec![
                BmuArtifact {
                    name: "som_bmu_tiny_gram".into(),
                    file: "som_bmu_tiny_gram.hlo.txt".into(),
                    shape: "tiny".into(),
                    variant: "gram".into(),
                    s: 256,
                    d: 16,
                    n: 256,
                    block_s: 64,
                    block_n: 64,
                },
            ],
        }
    }

    #[test]
    fn selects_smallest_fitting() {
        let m = fake_manifest();
        let a = m.select_som_step("gaussian", "planar", 10, 100).unwrap();
        assert_eq!(a.shape, "tiny");
        let a = m.select_som_step("gaussian", "planar", 100, 100).unwrap();
        assert_eq!(a.shape, "medium"); // dim 100 > 16 forces medium
    }

    #[test]
    fn no_fit_is_an_error() {
        let m = fake_manifest();
        assert!(m.select_som_step("gaussian", "toroid", 10, 10).is_err());
        assert!(m.select_som_step("gaussian", "planar", 10_000, 10).is_err());
        assert!(m.select_bmu("gram", 16, 256).is_ok());
        assert!(m.select_bmu("direct", 16, 256).is_err());
        assert!(m.select_bmu("gram", 17, 256).is_err());
        assert!(m.select_som_step("bubble", "planar", 10, 10_000).is_err());
    }

    #[test]
    fn parses_real_manifest_json() {
        let src = r#"{
          "som_step": [{
            "name": "som_step_tiny_gaussian_planar",
            "file": "som_step_tiny_gaussian_planar.hlo.txt",
            "shape": "tiny", "kind": "gaussian", "map_type": "planar",
            "s": 256, "d": 16, "n": 256, "block_s": 64, "block_n": 64,
            "inputs": ["data"], "outputs": ["bmus"]
          }],
          "umatrix": [{
            "name": "umatrix_tiny", "file": "umatrix_tiny.hlo.txt",
            "shape": "tiny", "n": 256, "k": 8, "d": 16,
            "inputs": [], "outputs": []
          }]
        }"#;
        let dir = std::env::temp_dir().join("somoclu_test_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), src).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.som_steps.len(), 1);
        assert_eq!(m.som_steps[0].s, 256);
        assert_eq!(m.umatrix[0].k, 8);
    }
}
