//! Accelerator kernel (paper `-k 1`) — the GPU kernel, reproduced as the
//! AOT-compiled JAX/Pallas epoch step executed through XLA/PJRT.
//!
//! The paper's GPU kernel computes the Gram matrix "using linear algebra
//! operations" and hybridizes with the CPU for the weight update; our
//! artifact fuses the whole shard-level epoch step (Pallas BMU search +
//! neighborhood + Pallas accumulation — see python/compile/model.py), so
//! one device execution per data chunk returns (bmus, num, den, qe).
//!
//! Marshaling strategy (the memory-frugality the paper emphasizes):
//! grid coordinates, node validity and wrap span are uploaded once per
//! map and stay device-resident; per epoch only the codebook is
//! re-uploaded; per chunk only the data rows + mask. Host staging
//! buffers are allocated once and reused across chunks and epochs.

use crate::kernels::{DataShard, EpochAccum, TrainingKernel};
use crate::runtime::{untuple, Engine, SomStepArtifact};
use crate::som::{Codebook, Grid, MapType, Neighborhood};

pub struct AccelKernel {
    engine: Engine,
    setup: Option<Setup>,
    /// Identity of the codebook `epoch_begin` opened an epoch for (see
    /// `codebook_key`): its device buffer is reused across that epoch's
    /// chunks. Calls with any other codebook re-upload every time.
    begin_key: Option<(usize, usize, usize, u64)>,
}

/// Per-(map, codebook-shape, neighborhood) device state.
struct Setup {
    art: SomStepArtifact,
    /// Logical sizes (unpadded).
    nodes: usize,
    dim: usize,
    kind: &'static str,
    map_type: MapType,
    grid_fingerprint: (usize, usize),
    /// Device-resident constants.
    coords_buf: xla::PjRtBuffer,
    valid_buf: xla::PjRtBuffer,
    span_buf: xla::PjRtBuffer,
    /// Device codebook for the current epoch (None = needs upload).
    cb_buf: Option<xla::PjRtBuffer>,
    /// Reused host staging.
    cb_padded: Vec<f32>,
    data_padded: Vec<f32>,
    mask: Vec<f32>,
}

impl AccelKernel {
    pub fn new(engine: Engine) -> Self {
        AccelKernel {
            engine,
            setup: None,
            begin_key: None,
        }
    }

    /// Engine over SOMOCLU_ARTIFACTS (or ./artifacts).
    pub fn from_env() -> anyhow::Result<Self> {
        Ok(Self::new(Engine::from_env()?))
    }

    fn ensure_setup(
        &mut self,
        grid: &Grid,
        nodes: usize,
        dim: usize,
        kind: &'static str,
    ) -> anyhow::Result<()> {
        let fingerprint = (grid.rows, grid.cols);
        if let Some(s) = &self.setup {
            if s.nodes == nodes
                && s.dim == dim
                && s.kind == kind
                && s.map_type == grid.map_type
                && s.grid_fingerprint == fingerprint
            {
                return Ok(());
            }
        }
        let map_type = match grid.map_type {
            MapType::Planar => "planar",
            MapType::Toroid => "toroid",
        };
        let art = self
            .engine
            .manifest()
            .select_som_step(kind, map_type, dim, nodes)?
            .clone();

        // Coordinates, validity, span: upload once.
        let mut coords = grid.coords_flat();
        coords.resize(art.n * 2, 0.0);
        let mut valid = vec![1.0f32; nodes];
        valid.resize(art.n, 0.0);
        let span = grid.span();

        let coords_buf = self.engine.to_device_f32(&coords, &[art.n, 2])?;
        let valid_buf = self.engine.to_device_f32(&valid, &[art.n])?;
        let span_buf = self.engine.to_device_f32(&span, &[2])?;

        // Pre-compile now so the first epoch isn't billed for it.
        self.engine.executable(&art.file)?;

        self.setup = Some(Setup {
            cb_padded: vec![0.0; art.n * art.d],
            data_padded: vec![0.0; art.s * art.d],
            mask: vec![0.0; art.s],
            art,
            nodes,
            dim,
            kind,
            map_type: grid.map_type,
            grid_fingerprint: fingerprint,
            coords_buf,
            valid_buf,
            span_buf,
            cb_buf: None,
        });
        Ok(())
    }
}

impl TrainingKernel for AccelKernel {
    fn name(&self) -> &'static str {
        "accel-xla"
    }

    fn epoch_begin(&mut self, codebook: &Codebook) -> anyhow::Result<()> {
        // New epoch: invalidate the device copy so the first chunk
        // re-uploads it, and let later same-codebook chunks reuse it.
        self.begin_key = Some(crate::kernels::codebook_key(codebook));
        if let Some(s) = self.setup.as_mut() {
            s.cb_buf = None;
        }
        Ok(())
    }

    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum> {
        let DataShard::Dense { data, dim } = shard else {
            anyhow::bail!(
                "accel kernel needs dense data (the paper's GPU kernel has no \
                 sparse variant either; use -k 2)"
            );
        };
        anyhow::ensure!(dim == codebook.dim, "dim mismatch");
        anyhow::ensure!(
            grid.node_count() == codebook.nodes,
            "grid/codebook mismatch"
        );
        let rows = data.len() / dim;
        let kind = neighborhood.artifact_kind();
        self.ensure_setup(grid, codebook.nodes, dim, kind)?;
        // Split borrows: engine and setup are separate fields.
        let setup = self.setup.as_mut().expect("just ensured");
        let engine = &mut self.engine;
        let (s_cap, d_pad, n_pad) = (setup.art.s, setup.art.d, setup.art.n);

        // Codebook upload (once per epoch; reused across chunks inside an
        // epoch_begin-scoped epoch for this exact codebook, refreshed per
        // call otherwise).
        if self.begin_key != Some(crate::kernels::codebook_key(codebook)) {
            setup.cb_buf = None;
        }
        if setup.cb_buf.is_none() {
            for node in 0..setup.nodes {
                setup.cb_padded[node * d_pad..node * d_pad + dim]
                    .copy_from_slice(codebook.row(node));
            }
            setup.cb_buf =
                Some(engine.to_device_f32(&setup.cb_padded, &[n_pad, d_pad])?);
        }
        let radius_buf = engine.to_device_f32(&[radius], &[])?;
        let scale_buf = engine.to_device_f32(&[scale], &[])?;

        let mut acc = EpochAccum::zeros(setup.nodes, dim, 0);
        let exe_file = setup.art.file.clone();

        let mut start = 0usize;
        while start < rows {
            let chunk = (rows - start).min(s_cap);
            // Stage rows + mask (padded tail zeroed).
            for r in 0..chunk {
                let src = &data[(start + r) * dim..(start + r + 1) * dim];
                let dst = &mut setup.data_padded[r * d_pad..r * d_pad + dim];
                dst.copy_from_slice(src);
                setup.mask[r] = 1.0;
            }
            for r in chunk..s_cap {
                setup.data_padded[r * d_pad..(r + 1) * d_pad].fill(0.0);
                setup.mask[r] = 0.0;
            }
            let data_buf =
                engine.to_device_f32(&setup.data_padded, &[s_cap, d_pad])?;
            let mask_buf = engine.to_device_f32(&setup.mask, &[s_cap])?;

            let exe = engine.executable(&exe_file)?;
            let cb_buf = setup.cb_buf.as_ref().expect("uploaded above");
            let outputs = exe.execute_b(&[
                &data_buf,
                &mask_buf,
                cb_buf,
                &setup.coords_buf,
                &setup.valid_buf,
                &setup.span_buf,
                &radius_buf,
                &scale_buf,
            ])?;
            let parts = untuple(outputs)?;
            anyhow::ensure!(parts.len() == 4, "expected 4 outputs");

            let bmus_chunk = parts[0].to_vec::<i32>()?;
            let num_chunk = parts[1].to_vec::<f32>()?;
            let den_chunk = parts[2].to_vec::<f32>()?;
            let qe_chunk: f32 = parts[3].get_first_element()?;

            acc.bmus
                .extend(bmus_chunk[..chunk].iter().map(|&b| b as u32));
            for node in 0..setup.nodes {
                let src = &num_chunk[node * d_pad..node * d_pad + dim];
                let dst = &mut acc.num[node * dim..(node + 1) * dim];
                for (a, b) in dst.iter_mut().zip(src) {
                    *a += b;
                }
            }
            for (a, b) in acc.den.iter_mut().zip(&den_chunk[..setup.nodes]) {
                *a += b;
            }
            acc.qe_sum += qe_chunk as f64;
            start += chunk;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_cpu::DenseCpuKernel;
    use crate::som::grid::GridType;
    use crate::util::rng::Rng;

    fn artifacts_available() -> bool {
        crate::runtime::Manifest::default_dir()
            .join("manifest.json")
            .exists()
    }

    /// accel kernel == dense CPU kernel (the cross-layer correctness
    /// anchor: rust CPU path vs Pallas/XLA path).
    #[test]
    fn matches_dense_cpu_kernel() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rng = Rng::new(42);
        for (map_type, nb) in [
            (MapType::Planar, Neighborhood::gaussian(false)),
            (MapType::Toroid, Neighborhood::gaussian(false)),
            (MapType::Planar, Neighborhood::bubble()),
            (MapType::Planar, Neighborhood::gaussian(true)),
        ] {
            let grid = Grid::new(10, 10, GridType::Square, map_type);
            let cb = Codebook::random_init(100, 12, &mut rng);
            let data: Vec<f32> = (0..300 * 12).map(|_| rng.normal_f32()).collect();
            let shard = DataShard::Dense {
                data: &data,
                dim: 12,
            };

            let mut accel = AccelKernel::from_env().unwrap();
            let got = accel
                .epoch_accumulate(shard, &cb, &grid, nb, 2.5, 0.9)
                .unwrap();
            let want = DenseCpuKernel::new(2)
                .epoch_accumulate(shard, &cb, &grid, nb, 2.5, 0.9)
                .unwrap();

            assert_eq!(got.bmus, want.bmus, "{map_type:?} {nb:?}");
            assert!(
                (got.qe_sum - want.qe_sum).abs() / want.qe_sum.max(1.0) < 1e-3,
                "{map_type:?}: qe {} vs {}",
                got.qe_sum,
                want.qe_sum
            );
            for (i, (a, b)) in got.num.iter().zip(&want.num).enumerate() {
                assert!(
                    (a - b).abs() < 2e-2 + 1e-3 * b.abs(),
                    "{map_type:?} num[{i}]: {a} vs {b}"
                );
            }
            for (a, b) in got.den.iter().zip(&want.den) {
                assert!((a - b).abs() < 2e-2 + 1e-3 * b.abs());
            }
        }
    }

    #[test]
    fn chunking_is_invisible() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        // 300 rows with tiny-config capacity 256 forces 2 chunks; the
        // result must equal the CPU kernel regardless (covered above),
        // and re-running must be deterministic.
        let mut rng = Rng::new(43);
        let grid = Grid::new(8, 8, GridType::Square, MapType::Planar);
        let cb = Codebook::random_init(64, 8, &mut rng);
        let data: Vec<f32> = (0..300 * 8).map(|_| rng.normal_f32()).collect();
        let shard = DataShard::Dense { data: &data, dim: 8 };
        let mut k = AccelKernel::from_env().unwrap();
        let nb = Neighborhood::gaussian(false);
        let a = k.epoch_accumulate(shard, &cb, &grid, nb, 2.0, 1.0).unwrap();
        let b = k.epoch_accumulate(shard, &cb, &grid, nb, 2.0, 1.0).unwrap();
        assert_eq!(a.bmus, b.bmus);
        assert_eq!(a.num, b.num);
    }

    #[test]
    fn rejects_sparse() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(4, 2);
        let m = crate::sparse::Csr::new_empty(2, 2);
        let mut k = AccelKernel::from_env().unwrap();
        assert!(k
            .epoch_accumulate(
                DataShard::Sparse(m.view()),
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }
}
