//! Hybrid kernel — the paper's actual GPU design (§3.1): the accelerator
//! does the data-parallel distance search, the CPU threads do the weight
//! update. "While the GPU handles the load efficiently, it would be
//! highly inefficient to use a single thread to update the local
//! weights. We thus hybridized the kernel and rely on OpenMP to
//! parallelize the weight update."
//!
//! Here: BMU search runs the AOT `som_bmu_*` artifact through PJRT; the
//! Eq. 6 accumulation reuses the node-parallel CPU scheme of the dense
//! kernel (BMU-histogram formulation).

use crate::kernels::dense_cpu::accumulate_node_parallel_with;
use crate::kernels::{AccumConfig, DataShard, EpochAccum, SweepMode, TrainingKernel};
use crate::runtime::{untuple, Engine};
use crate::som::{Codebook, Grid, Neighborhood, StencilCache};

pub struct HybridKernel {
    engine: Engine,
    pub threads: usize,
    /// Which BMU formulation to run on the accelerator: "gram" (default,
    /// the paper's pick) or "direct" (ablation baseline).
    pub variant: &'static str,
    setup: Option<Setup>,
    /// Identity of the codebook `epoch_begin` opened an epoch for (see
    /// `codebook_key`): its device buffer is reused across that epoch's
    /// chunks. Calls with any other codebook re-upload every time.
    begin_key: Option<(usize, usize, usize, u64)>,
    /// Phase B stencil memo (built once per epoch, reused per chunk).
    stencil: StencilCache,
}

struct Setup {
    file: String,
    s: usize,
    d: usize,
    n: usize,
    nodes: usize,
    dim: usize,
    valid_buf: xla::PjRtBuffer,
    /// Device codebook for the current epoch (None = needs upload).
    cb_buf: Option<xla::PjRtBuffer>,
    cb_padded: Vec<f32>,
    data_padded: Vec<f32>,
}

impl HybridKernel {
    pub fn new(engine: Engine, threads: usize) -> Self {
        HybridKernel {
            engine,
            threads: threads.max(1),
            variant: "gram",
            setup: None,
            begin_key: None,
            stencil: StencilCache::new(),
        }
    }

    pub fn from_env(threads: usize) -> anyhow::Result<Self> {
        Ok(Self::new(Engine::from_env()?, threads))
    }

    pub fn with_variant(mut self, variant: &'static str) -> Self {
        self.variant = variant;
        self.setup = None;
        self
    }

    fn ensure_setup(&mut self, nodes: usize, dim: usize) -> anyhow::Result<()> {
        if let Some(s) = &self.setup {
            if s.nodes == nodes && s.dim == dim {
                return Ok(());
            }
        }
        let art = self.engine.manifest().select_bmu(self.variant, dim, nodes)?.clone();
        let mut valid = vec![1.0f32; nodes];
        valid.resize(art.n, 0.0);
        let valid_buf = self.engine.to_device_f32(&valid, &[art.n])?;
        self.engine.executable(&art.file)?;
        self.setup = Some(Setup {
            cb_padded: vec![0.0; art.n * art.d],
            data_padded: vec![0.0; art.s * art.d],
            file: art.file,
            s: art.s,
            d: art.d,
            n: art.n,
            nodes,
            dim,
            valid_buf,
            cb_buf: None,
        });
        Ok(())
    }
}

impl TrainingKernel for HybridKernel {
    fn name(&self) -> &'static str {
        "hybrid-xla-cpu"
    }

    fn epoch_begin(&mut self, codebook: &Codebook) -> anyhow::Result<()> {
        // New epoch: invalidate the device copy so the first chunk
        // re-uploads it, and let later same-codebook chunks reuse it.
        self.begin_key = Some(crate::kernels::codebook_key(codebook));
        if let Some(s) = self.setup.as_mut() {
            s.cb_buf = None;
        }
        Ok(())
    }

    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum> {
        let DataShard::Dense { data, dim } = shard else {
            anyhow::bail!("hybrid kernel needs dense data");
        };
        anyhow::ensure!(dim == codebook.dim, "dim mismatch");
        let rows = data.len() / dim;
        self.ensure_setup(codebook.nodes, dim)?;
        let setup = self.setup.as_mut().expect("just ensured");
        let engine = &mut self.engine;
        let (s_cap, d_pad) = (setup.s, setup.d);

        // --- Accelerator phase: BMU search per device batch.
        // Reuse the device codebook only within an epoch_begin-scoped
        // epoch for this exact codebook; otherwise re-upload per call.
        if self.begin_key != Some(crate::kernels::codebook_key(codebook)) {
            setup.cb_buf = None;
        }
        if setup.cb_buf.is_none() {
            for node in 0..setup.nodes {
                setup.cb_padded[node * d_pad..node * d_pad + dim]
                    .copy_from_slice(codebook.row(node));
            }
            setup.cb_buf =
                Some(engine.to_device_f32(&setup.cb_padded, &[setup.n, d_pad])?);
        }

        let mut bmus: Vec<u32> = Vec::with_capacity(rows);
        let mut qe_sum = 0.0f64;
        let mut start = 0usize;
        while start < rows {
            let chunk = (rows - start).min(s_cap);
            for r in 0..chunk {
                let src = &data[(start + r) * dim..(start + r + 1) * dim];
                setup.data_padded[r * d_pad..r * d_pad + dim].copy_from_slice(src);
            }
            for r in chunk..s_cap {
                setup.data_padded[r * d_pad..(r + 1) * d_pad].fill(0.0);
            }
            let data_buf = engine.to_device_f32(&setup.data_padded, &[s_cap, d_pad])?;
            let exe = engine.executable(&setup.file)?;
            let cb_buf = setup.cb_buf.as_ref().expect("uploaded above");
            let parts = untuple(exe.execute_b(&[&data_buf, cb_buf, &setup.valid_buf])?)?;
            anyhow::ensure!(parts.len() == 2, "expected 2 outputs");
            let best = parts[0].to_vec::<f32>()?;
            let idx = parts[1].to_vec::<i32>()?;
            for r in 0..chunk {
                bmus.push(idx[r] as u32);
                qe_sum += (best[r].max(0.0) as f64).sqrt();
            }
            start += chunk;
        }

        // --- CPU phase: threaded Eq. 6 accumulation (the OpenMP side).
        let threads = self.threads;
        let (num, den, _) = accumulate_node_parallel_with(
            &AccumConfig {
                rows,
                nodes: codebook.nodes,
                dim,
                threads,
                grid,
                neighborhood,
                radius,
                scale,
                mode: SweepMode::Auto,
            },
            &bmus,
            |num_row, r, h| {
                let x = &data[r * dim..(r + 1) * dim];
                for (acc, v) in num_row.iter_mut().zip(x) {
                    *acc = v.mul_add(h, *acc);
                }
            },
            self.stencil.get(grid, neighborhood, radius, scale),
        );

        Ok(EpochAccum {
            bmus,
            num,
            den,
            qe_sum,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_cpu::DenseCpuKernel;
    use crate::som::grid::{GridType, MapType};
    use crate::util::rng::Rng;

    fn artifacts_available() -> bool {
        crate::runtime::Manifest::default_dir()
            .join("manifest.json")
            .exists()
    }

    #[test]
    fn hybrid_matches_dense_cpu() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rng = Rng::new(61);
        let grid = Grid::new(9, 9, GridType::Square, MapType::Toroid);
        let cb = Codebook::random_init(81, 10, &mut rng);
        let data: Vec<f32> = (0..300 * 10).map(|_| rng.normal_f32()).collect();
        let shard = DataShard::Dense { data: &data, dim: 10 };
        let nb = Neighborhood::gaussian(false);

        let want = DenseCpuKernel::new(2)
            .epoch_accumulate(shard, &cb, &grid, nb, 3.0, 0.8)
            .unwrap();
        for variant in ["gram", "direct"] {
            let mut k = HybridKernel::from_env(2).unwrap().with_variant(variant);
            let got = k.epoch_accumulate(shard, &cb, &grid, nb, 3.0, 0.8).unwrap();
            assert_eq!(got.bmus, want.bmus, "{variant}");
            assert!(
                (got.qe_sum - want.qe_sum).abs() / want.qe_sum < 1e-3,
                "{variant}: {} vs {}",
                got.qe_sum,
                want.qe_sum
            );
            for (a, b) in got.num.iter().zip(&want.num) {
                assert!((a - b).abs() < 1e-2 + 1e-3 * b.abs(), "{variant}");
            }
            for (a, b) in got.den.iter().zip(&want.den) {
                assert!((a - b).abs() < 1e-2 + 1e-3 * b.abs(), "{variant}");
            }
        }
    }

    #[test]
    fn rejects_sparse() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(4, 2);
        let m = crate::sparse::Csr::new_empty(2, 2);
        let mut k = HybridKernel::from_env(1).unwrap();
        assert!(k
            .epoch_accumulate(
                DataShard::Sparse(m.view()),
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }
}
