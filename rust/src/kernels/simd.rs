//! BMU-search microkernel: cache-blocked codebook panels with runtime
//! SIMD dispatch (ISSUE 6 tentpole).
//!
//! The O(S·N·D) distance search is a disguised GEMM — `argmin_n ||x||² +
//! ||w_n||² − 2·x·w_n = argmin_n (||w_n||²/2 − x·w_n)` — and after the
//! stencil accumulator (ISSUE 5) it dominates every lane of
//! `benches/profile_epoch.rs`. So it gets GEMM treatment:
//!
//! * **Register blocking** — 8 data rows share each codebook row
//!   ([`BLOCK_ROWS`]; ≈ the ymm register budget), computed by an 8-way
//!   FMA dot kernel ([`dot8`]).
//! * **Cache blocking** — the codebook is cut into L2-resident
//!   *N-panels* ([`default_panel_nodes`]): each panel streams from DRAM
//!   once and is then re-read from L2 by every 8-row block in a worker's
//!   range, instead of the whole N·D codebook streaming from DRAM once
//!   per block. No packed/transposed layout is needed: the codebook is
//!   row-major, so an N-panel is already one contiguous slab, and
//!   repacking could only perturb the dot-product bit patterns the
//!   exact-BMU contract pins.
//! * **One dispatch point** — [`dispatch`] detects AVX2+FMA once per
//!   process (overridable with `SOMOCLU_FORCE_SCALAR=1` for debugging)
//!   and every scan takes the resolved [`SimdKind`] as a parameter, so
//!   the hot loops contain no per-call feature detection.
//!
//! ## The exact-BMU contract
//!
//! For a fixed [`SimdKind`], every function here produces **bit-identical**
//! scores, argmin indices, and reconstructed distances to the pre-panel
//! 8-row block scan (`rust/tests/bmu_search_equivalence.rs` pins this
//! against a verbatim copy of the old code):
//!
//! * the AVX2 `dot8` kernel is unchanged byte for byte;
//! * the scalar `dot8` is 8× [`dot_unrolled`], the historical scalar
//!   fallback, bit for bit;
//! * panel tiling only re-nests the loops — each row still visits nodes
//!   in ascending index order, so the `score < best` running argmin
//!   (ties resolved to the **lowest node index**, including across panel
//!   boundaries) evolves through the exact same sequence of updates.
//!
//! Scalar and AVX2 kinds are *not* bit-identical to each other (their
//! dot reduction trees differ, as they always have); the contract is
//! per-kind, matching what the pre-refactor per-call detection selected
//! on the same machine.

use std::sync::OnceLock;

/// Data rows per register block: each codebook row is loaded once per
/// block and shared by all 8 row accumulators.
pub const BLOCK_ROWS: usize = 8;

/// Which BMU-search kernel runs. Resolved once per process by
/// [`dispatch`]; every scan in this module takes it as an explicit
/// parameter so tests can pin a kind without touching the environment.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SimdKind {
    /// Portable scalar kernel ([`dot_unrolled`] ×8). Forced by
    /// `SOMOCLU_FORCE_SCALAR=1`.
    Scalar,
    /// Explicit AVX2+FMA intrinsics (x86-64 with both features).
    Avx2Fma,
}

/// Human-readable kernel name (`somoclu` prints it in its run summary;
/// see also [`active_kernel_name`]).
pub fn kernel_name(kind: SimdKind) -> &'static str {
    match kind {
        SimdKind::Scalar => "scalar",
        SimdKind::Avx2Fma => "avx2+fma",
    }
}

/// The one feature-detection point: AVX2+FMA on x86-64 unless
/// `SOMOCLU_FORCE_SCALAR` is set to anything but `0`/empty, scalar
/// otherwise. Cached for the process lifetime — the hot loops never
/// re-detect (the pre-refactor code ran `is_x86_feature_detected!` per
/// 8-row dot call).
pub fn dispatch() -> SimdKind {
    static KIND: OnceLock<SimdKind> = OnceLock::new();
    *KIND.get_or_init(|| {
        let forced = std::env::var("SOMOCLU_FORCE_SCALAR")
            .is_ok_and(|v| !v.is_empty() && v != "0");
        if forced {
            return SimdKind::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdKind::Avx2Fma;
        }
        SimdKind::Scalar
    })
}

/// Name of the kernel [`dispatch`] resolved for this process.
pub fn active_kernel_name() -> &'static str {
    kernel_name(dispatch())
}

/// L2 budget for one codebook panel. Half of a conservative 512 KiB L2:
/// the other half keeps the 8 active data rows, their accumulators, and
/// the panel's ||w||² slice resident alongside.
pub const PANEL_BYTES: usize = 256 * 1024;

/// Codebook rows per L2 panel for dimension `dim`: the largest panel
/// whose f32 payload fits [`PANEL_BYTES`], floored at [`BLOCK_ROWS`].
/// Override with `SOMOCLU_BMU_PANEL=<nodes>` (read once per process;
/// the blocked-scan entry points also take the panel size as an explicit
/// parameter, which is what the panel-sweep tests use).
pub fn default_panel_nodes(dim: usize) -> usize {
    static OVERRIDE: OnceLock<Option<usize>> = OnceLock::new();
    let over = *OVERRIDE.get_or_init(|| {
        std::env::var("SOMOCLU_BMU_PANEL")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
    });
    if let Some(n) = over {
        return n;
    }
    (PANEL_BYTES / (4 * dim.max(1))).max(BLOCK_ROWS)
}

/// Dot product with 8 independent accumulators: breaks the sequential
/// FP dependency chain so the compiler vectorizes + pipelines it (§Perf:
/// 4.5x on the BMU search vs the naive single-accumulator loop). This is
/// the historical scalar kernel — its reduction order is pinned by the
/// equivalence suite, so [`SimdKind::Scalar`] results never move.
#[inline]
pub fn dot_unrolled(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let chunks = x.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let xb = &x[c * 8..c * 8 + 8];
        let wb = &w[c * 8..c * 8 + 8];
        for k in 0..8 {
            acc[k] = xb[k].mul_add(wb[k], acc[k]);
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..x.len() {
        tail = x[i].mul_add(w[i], tail);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// Eight dot products against a shared `w`, using the kernel `kind`
/// selects.
///
/// On AVX2+FMA this is explicit intrinsics: LLVM's auto-vectorizer turns
/// the natural nested loop into cross-row shuffle soup (xmm
/// inserts/shuffles around each FMA — measured 5x off peak), while the
/// intrinsic kernel is 8 packed FMAs + 9 contiguous loads per 8-lane
/// chunk and the shared `w` load amortizes across all rows. AVX-512 was
/// tried and reverted: no gain over AVX2 on this part (single 512-bit
/// FMA unit + downclock) — see EXPERIMENTS.md §Perf.
#[inline]
pub fn dot8(kind: SimdKind, x: &[&[f32]; BLOCK_ROWS], w: &[f32]) -> [f32; BLOCK_ROWS] {
    #[cfg(target_arch = "x86_64")]
    if kind == SimdKind::Avx2Fma {
        // SAFETY: Avx2Fma is only resolved by `dispatch` (or passed by
        // tests) on hosts with avx2+fma; slices are read in 8-lane
        // chunks strictly within bounds.
        return unsafe { dot8_avx2(x, w) };
    }
    let _ = kind;
    dot8_scalar(x, w)
}

/// Scalar `dot8`: 8 independent [`dot_unrolled`] calls — bit-identical
/// to the pre-refactor scalar fallback.
#[inline]
pub fn dot8_scalar(x: &[&[f32]; BLOCK_ROWS], w: &[f32]) -> [f32; BLOCK_ROWS] {
    let mut out = [0.0f32; BLOCK_ROWS];
    for k in 0..BLOCK_ROWS {
        out[k] = dot_unrolled(x[k], w);
    }
    out
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot8_avx2(x: &[&[f32]; 8], w: &[f32]) -> [f32; 8] {
    use std::arch::x86_64::*;
    let d = w.len();
    let chunks = d / 8;
    unsafe {
        let mut acc = [_mm256_setzero_ps(); 8];
        let wp = w.as_ptr();
        let xp: [*const f32; 8] = std::array::from_fn(|k| x[k].as_ptr());
        for c in 0..chunks {
            let o = (c * 8) as isize;
            let wv = _mm256_loadu_ps(wp.offset(o));
            for k in 0..8 {
                acc[k] =
                    _mm256_fmadd_ps(_mm256_loadu_ps(xp[k].offset(o)), wv, acc[k]);
            }
        }
        #[inline]
        unsafe fn hsum(v: std::arch::x86_64::__m256) -> f32 {
            unsafe {
                let lo = _mm256_castps256_ps128(v);
                let hi = _mm256_extractf128_ps(v, 1);
                let s = _mm_add_ps(lo, hi);
                let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
                let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
                _mm_cvtss_f32(s)
            }
        }
        let mut out: [f32; 8] = std::array::from_fn(|k| hsum(acc[k]));
        for i in chunks * 8..d {
            for k in 0..8 {
                out[k] = x[k][i].mul_add(w[i], out[k]);
            }
        }
        out
    }
}

/// Fold one node's scores into the running argmin of a row block.
/// Strict `<` keeps the lowest node index on exact ties — the tie rule
/// the whole search contract pins — and rejects NaN scores.
#[inline(always)]
fn argmin_update(
    n: u32,
    half_w2: f32,
    dots: &[f32; BLOCK_ROWS],
    blen: usize,
    best: &mut [u32; BLOCK_ROWS],
    score: &mut [f32; BLOCK_ROWS],
) {
    for k in 0..blen {
        let s = half_w2 - dots[k];
        if s < score[k] {
            score[k] = s;
            best[k] = n;
        }
    }
}

/// Scan one codebook panel for a block of ≤ 8 data rows, folding into
/// the rows' running argmin state.
///
/// * `x` — the block's row slices (lanes `blen..` are padding and their
///   results are discarded);
/// * `panel` — codebook rows `[n0, n0 + panel_len)`, contiguous row-major
///   (`panel.len() == panel_len * dim`);
/// * `w2` — matching `||w||²` slice (`panel_len` entries);
/// * `best`/`score` — running argmin per lane, updated in place. `score`
///   holds the Gram score `||w||²/2 − x·w`; callers reconstruct the true
///   squared distance as `(||x||² + 2·score).max(0)`.
///
/// Nodes are visited in ascending index order, so driving this panel by
/// panel (ascending `n0`) replays exactly the flat scan's update
/// sequence — the bit-identity and lowest-index-tie guarantees hold
/// across panel boundaries.
#[allow(clippy::too_many_arguments)]
pub fn bmu_scan_panel(
    kind: SimdKind,
    x: &[&[f32]; BLOCK_ROWS],
    blen: usize,
    panel: &[f32],
    dim: usize,
    w2: &[f32],
    n0: u32,
    best: &mut [u32; BLOCK_ROWS],
    score: &mut [f32; BLOCK_ROWS],
) {
    debug_assert!(dim > 0 && panel.len() == w2.len() * dim);
    #[cfg(target_arch = "x86_64")]
    if kind == SimdKind::Avx2Fma {
        // SAFETY: kind contract as in `dot8`.
        unsafe { bmu_scan_panel_avx2(x, blen, panel, dim, w2, n0, best, score) };
        return;
    }
    let _ = kind;
    for (i, w) in panel.chunks_exact(dim).enumerate() {
        let dots = dot8_scalar(x, w);
        argmin_update(n0 + i as u32, 0.5 * w2[i], &dots, blen, best, score);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn bmu_scan_panel_avx2(
    x: &[&[f32]; BLOCK_ROWS],
    blen: usize,
    panel: &[f32],
    dim: usize,
    w2: &[f32],
    n0: u32,
    best: &mut [u32; BLOCK_ROWS],
    score: &mut [f32; BLOCK_ROWS],
) {
    for (i, w) in panel.chunks_exact(dim).enumerate() {
        // SAFETY: caller guarantees avx2+fma; `w` has `dim` elements and
        // each `x` lane at least `dim`.
        let dots = unsafe { dot8_avx2(x, w) };
        argmin_update(n0 + i as u32, 0.5 * w2[i], &dots, blen, best, score);
    }
}

/// Fold one node's scores into the running top-2 of a row block. Strict
/// `<` everywhere: on exact ties both the best and the runner-up keep
/// the lowest qualifying node index. A node never ties itself into both
/// slots — the `else` arm only sees nodes that did not displace `b1`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn top2_update(
    n: u32,
    half_w2: f32,
    dots: &[f32; BLOCK_ROWS],
    blen: usize,
    b1: &mut [u32; BLOCK_ROWS],
    s1: &mut [f32; BLOCK_ROWS],
    b2: &mut [u32; BLOCK_ROWS],
    s2: &mut [f32; BLOCK_ROWS],
) {
    for k in 0..blen {
        let s = half_w2 - dots[k];
        if s < s1[k] {
            s2[k] = s1[k];
            b2[k] = b1[k];
            s1[k] = s;
            b1[k] = n;
        } else if s < s2[k] {
            s2[k] = s;
            b2[k] = n;
        }
    }
}

/// [`bmu_scan_panel`]'s top-2 sibling: maintains the best *and second
/// best* node per lane (the topographic-error scan in
/// [`crate::som::quality::best_two`]). Same panel layout, same ascending
/// visit order, same lowest-index tie rule.
#[allow(clippy::too_many_arguments)]
pub fn top2_scan_panel(
    kind: SimdKind,
    x: &[&[f32]; BLOCK_ROWS],
    blen: usize,
    panel: &[f32],
    dim: usize,
    w2: &[f32],
    n0: u32,
    b1: &mut [u32; BLOCK_ROWS],
    s1: &mut [f32; BLOCK_ROWS],
    b2: &mut [u32; BLOCK_ROWS],
    s2: &mut [f32; BLOCK_ROWS],
) {
    debug_assert!(dim > 0 && panel.len() == w2.len() * dim);
    #[cfg(target_arch = "x86_64")]
    if kind == SimdKind::Avx2Fma {
        // SAFETY: kind contract as in `dot8`.
        unsafe { top2_scan_panel_avx2(x, blen, panel, dim, w2, n0, b1, s1, b2, s2) };
        return;
    }
    let _ = kind;
    for (i, w) in panel.chunks_exact(dim).enumerate() {
        let dots = dot8_scalar(x, w);
        top2_update(n0 + i as u32, 0.5 * w2[i], &dots, blen, b1, s1, b2, s2);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn top2_scan_panel_avx2(
    x: &[&[f32]; BLOCK_ROWS],
    blen: usize,
    panel: &[f32],
    dim: usize,
    w2: &[f32],
    n0: u32,
    b1: &mut [u32; BLOCK_ROWS],
    s1: &mut [f32; BLOCK_ROWS],
    b2: &mut [u32; BLOCK_ROWS],
    s2: &mut [f32; BLOCK_ROWS],
) {
    for (i, w) in panel.chunks_exact(dim).enumerate() {
        // SAFETY: caller guarantees avx2+fma; bounds as in bmu_scan_panel.
        let dots = unsafe { dot8_avx2(x, w) };
        top2_update(n0 + i as u32, 0.5 * w2[i], &dots, blen, b1, s1, b2, s2);
    }
}

/// Argmin over precomputed dot products — the sparse kernel's
/// dense-codebook side: given `dots[n] = x·w_n` (built by its CSR axpy
/// sweep) and `w2[n] = ||w_n||²`, return the node minimizing the Gram
/// score `||w||²/2 − x·w` plus that winning score, ties to the lowest
/// index.
///
/// Both kinds compute the score with the same two ops (`0.5 * w2[n]`,
/// then the subtraction — never a fused multiply-sub, which would round
/// differently) and reproduce the scalar scan's selection rule exactly,
/// so the result is bit-identical across [`SimdKind`]s *and* to the
/// pre-refactor scalar loop.
pub fn argmin_scored(kind: SimdKind, w2: &[f32], dots: &[f32]) -> (u32, f32) {
    debug_assert_eq!(w2.len(), dots.len());
    #[cfg(target_arch = "x86_64")]
    if kind == SimdKind::Avx2Fma {
        // SAFETY: kind contract as in `dot8`.
        return unsafe { argmin_scored_avx2(w2, dots) };
    }
    let _ = kind;
    argmin_scored_scalar(w2, dots, 0, (0, f32::INFINITY))
}

/// Scalar scan from node `n0`, continuing a running `(best, score)`
/// state (strict `<`, so earlier candidates win ties — and NaN scores
/// are never selected).
fn argmin_scored_scalar(
    w2: &[f32],
    dots: &[f32],
    n0: usize,
    mut state: (u32, f32),
) -> (u32, f32) {
    for (n, (&w, &d)) in w2.iter().zip(dots).enumerate().skip(n0) {
        let s = 0.5 * w - d;
        if s < state.1 {
            state = (n as u32, s);
        }
    }
    state
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn argmin_scored_avx2(w2: &[f32], dots: &[f32]) -> (u32, f32) {
    use std::arch::x86_64::*;
    let n = w2.len();
    let chunks = n / 8;
    let mut state = (0u32, f32::INFINITY);
    if chunks > 0 {
        // SAFETY: 8-lane loads within `chunks * 8 <= n`.
        unsafe {
            let half = _mm256_set1_ps(0.5);
            let mut best_s = _mm256_set1_ps(f32::INFINITY);
            let mut best_i = _mm256_setzero_si256();
            let mut idx = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
            let eight = _mm256_set1_epi32(8);
            for c in 0..chunks {
                let w = _mm256_loadu_ps(w2.as_ptr().add(c * 8));
                let d = _mm256_loadu_ps(dots.as_ptr().add(c * 8));
                // mul then sub — two roundings, same as the scalar scan
                // (a fused _mm256_fmsub_ps would change the bits).
                let s = _mm256_sub_ps(_mm256_mul_ps(half, w), d);
                let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(s, best_s);
                best_s = _mm256_blendv_ps(best_s, s, lt);
                best_i =
                    _mm256_blendv_epi8(best_i, idx, _mm256_castps_si256(lt));
                idx = _mm256_add_epi32(idx, eight);
            }
            let mut lane_s = [0.0f32; 8];
            let mut lane_i = [0i32; 8];
            _mm256_storeu_ps(lane_s.as_mut_ptr(), best_s);
            _mm256_storeu_si256(lane_i.as_mut_ptr() as *mut __m256i, best_i);
            // Each lane kept the lowest index among its own (mod-8) ties;
            // across lanes an explicit index comparison restores the
            // global lowest-index rule.
            for k in 0..8 {
                let (i, s) = (lane_i[k] as u32, lane_s[k]);
                if s < state.1 || (s == state.1 && i < state.0) {
                    state = (i, s);
                }
            }
        }
    }
    // Tail nodes have higher indices than every vector candidate, so the
    // strict `<` of the scalar continuation is the correct tie rule.
    argmin_scored_scalar(w2, dots, chunks * 8, state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn dispatch_is_stable_and_named() {
        let k = dispatch();
        assert_eq!(k, dispatch());
        assert!(!kernel_name(k).is_empty());
        assert_eq!(active_kernel_name(), kernel_name(k));
    }

    #[test]
    fn panel_sizing_tracks_dim() {
        // Unless the env override is set, panels shrink as dim grows and
        // never drop below one register block.
        if std::env::var_os("SOMOCLU_BMU_PANEL").is_some() {
            return;
        }
        assert!(default_panel_nodes(8) >= default_panel_nodes(256));
        assert!(default_panel_nodes(1 << 20) >= BLOCK_ROWS);
        assert_eq!(default_panel_nodes(32), PANEL_BYTES / (4 * 32));
    }

    #[test]
    fn scalar_dot8_is_eight_dot_unrolled() {
        let mut rng = Rng::new(1);
        for dim in [1usize, 7, 8, 9, 16, 33] {
            let rows: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, dim)).collect();
            let w = rand_vec(&mut rng, dim);
            let x: [&[f32]; 8] = std::array::from_fn(|k| rows[k].as_slice());
            let got = dot8(SimdKind::Scalar, &x, &w);
            for k in 0..8 {
                assert_eq!(got[k].to_bits(), dot_unrolled(&rows[k], &w).to_bits());
            }
        }
    }

    #[test]
    fn dispatched_dot8_close_to_f64_oracle() {
        // Cross-kind bits may differ; both must sit within f32 rounding
        // of the f64 dot.
        let mut rng = Rng::new(2);
        for dim in [5usize, 8, 64, 130] {
            let rows: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, dim)).collect();
            let w = rand_vec(&mut rng, dim);
            let x: [&[f32]; 8] = std::array::from_fn(|k| rows[k].as_slice());
            let got = dot8(dispatch(), &x, &w);
            for k in 0..8 {
                let oracle: f64 = rows[k]
                    .iter()
                    .zip(&w)
                    .map(|(a, b)| *a as f64 * *b as f64)
                    .sum();
                let tol = 1e-5 * (1.0 + oracle.abs());
                assert!(
                    ((got[k] as f64) - oracle).abs() < tol,
                    "dim {dim} lane {k}: {} vs {oracle}",
                    got[k]
                );
            }
        }
    }

    #[test]
    fn argmin_scored_kinds_agree_bit_for_bit() {
        // Identical score inputs ⇒ identical selection in every kind,
        // including exact ties and NaN lanes.
        let mut rng = Rng::new(3);
        for n in [1usize, 2, 7, 8, 9, 16, 100, 257] {
            let w2: Vec<f32> = (0..n).map(|_| rng.range_f32(0.0, 4.0)).collect();
            let mut dots = rand_vec(&mut rng, n);
            if n > 4 {
                // Manufacture an exact tie: same (w2, dot) pair twice.
                let (lo, hi) = (n / 4, n / 2);
                dots[hi] = dots[lo];
            }
            let scalar = argmin_scored(SimdKind::Scalar, &w2, &dots);
            let auto = argmin_scored(dispatch(), &w2, &dots);
            assert_eq!(scalar.0, auto.0, "n={n}");
            assert_eq!(scalar.1.to_bits(), auto.1.to_bits(), "n={n}");
        }
    }

    #[test]
    fn argmin_scored_tie_takes_lowest_index() {
        // All-equal scores: node 0 wins in every kind.
        let w2 = vec![2.0f32; 40];
        let dots = vec![0.5f32; 40];
        for kind in [SimdKind::Scalar, dispatch()] {
            let (b, s) = argmin_scored(kind, &w2, &dots);
            assert_eq!(b, 0);
            assert_eq!(s, 0.5);
        }
    }

    #[test]
    fn argmin_scored_ignores_nan_lanes() {
        // A NaN score is never selected (strict `<` semantics).
        let w2 = vec![f32::NAN, 2.0, 4.0];
        let dots = vec![0.0f32, 0.0, 0.0];
        for kind in [SimdKind::Scalar, dispatch()] {
            let (b, s) = argmin_scored(kind, &w2, &dots);
            assert_eq!(b, 1, "{kind:?}");
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn bmu_scan_matches_flat_argmin() {
        let mut rng = Rng::new(4);
        for (nodes, dim) in [(1usize, 3usize), (5, 8), (33, 17), (64, 32)] {
            let panel = rand_vec(&mut rng, nodes * dim);
            let w2: Vec<f32> = panel
                .chunks_exact(dim)
                .map(|w| w.iter().map(|v| v * v).sum())
                .collect();
            let rows: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, dim)).collect();
            let x: [&[f32]; 8] = std::array::from_fn(|k| rows[k].as_slice());
            for kind in [SimdKind::Scalar, dispatch()] {
                let mut best = [0u32; 8];
                let mut score = [f32::INFINITY; 8];
                bmu_scan_panel(kind, &x, 8, &panel, dim, &w2, 0, &mut best, &mut score);
                for k in 0..8 {
                    let (mut wb, mut ws) = (0u32, f32::INFINITY);
                    for n in 0..nodes {
                        let dots = dot8(kind, &x, &panel[n * dim..(n + 1) * dim]);
                        let s = 0.5 * w2[n] - dots[k];
                        if s < ws {
                            ws = s;
                            wb = n as u32;
                        }
                    }
                    assert_eq!(best[k], wb, "{kind:?} lane {k}");
                    assert_eq!(score[k].to_bits(), ws.to_bits(), "{kind:?} lane {k}");
                }
            }
        }
    }

    #[test]
    fn top2_scan_keeps_distinct_ordered_pair() {
        let mut rng = Rng::new(5);
        let (nodes, dim) = (24usize, 12usize);
        let panel = rand_vec(&mut rng, nodes * dim);
        let w2: Vec<f32> = panel
            .chunks_exact(dim)
            .map(|w| w.iter().map(|v| v * v).sum())
            .collect();
        let rows: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, dim)).collect();
        let x: [&[f32]; 8] = std::array::from_fn(|k| rows[k].as_slice());
        for kind in [SimdKind::Scalar, dispatch()] {
            let mut b1 = [0u32; 8];
            let mut s1 = [f32::INFINITY; 8];
            let mut b2 = [0u32; 8];
            let mut s2 = [f32::INFINITY; 8];
            top2_scan_panel(
                kind, &x, 8, &panel, dim, &w2, 0, &mut b1, &mut s1, &mut b2, &mut s2,
            );
            for k in 0..8 {
                assert_ne!(b1[k], b2[k], "{kind:?} lane {k}");
                assert!(s1[k] <= s2[k], "{kind:?} lane {k}");
                // b1 must agree with the argmin scan.
                let mut best = [0u32; 8];
                let mut score = [f32::INFINITY; 8];
                bmu_scan_panel(kind, &x, 8, &panel, dim, &w2, 0, &mut best, &mut score);
                assert_eq!(b1[k], best[k]);
                assert_eq!(s1[k].to_bits(), score[k].to_bits());
            }
        }
    }
}
