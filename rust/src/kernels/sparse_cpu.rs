//! Sparse CPU kernel (paper `-k 2`) — "a straightforward extension of the
//! dense CPU kernel, and its main virtue is the reduced memory use".
//!
//! BMU search uses the Gram trick over CSR rows:
//!     argmin_n ||x||² + ||w_n||² − 2 Σ_{c ∈ nnz(x)} x_c · w_n[c]
//!         = argmin_n (||w_n||²/2 − Σ_{nz} x_c w_n[c])
//! so the inner loop touches only the nonzeros of the data row (the
//! codebook stays dense — "the code book is always a dense structure,
//! even if the training data is sparse", §3.2).
//!
//! Accumulation reuses the node-parallel scheme of the dense kernel with
//! a sparse axpy. The paper notes there is no GPU variant of this kernel
//! (irregular access patterns); likewise we offer no accel variant.

use crate::kernels::dense_cpu::accumulate_node_parallel_with;
use crate::kernels::simd;
use crate::kernels::{AccumConfig, DataShard, EpochAccum, SweepMode, TrainingKernel};
use crate::som::{Codebook, Grid, Neighborhood, StencilCache};
use crate::util::threadpool;

pub struct SparseCpuKernel {
    pub threads: usize,
    /// Transposed codebook [dim x nodes], rebuilt per epoch (§Perf): the
    /// BMU scores then accumulate with *contiguous* axpy sweeps
    /// `scores[:] += v · wT[c, :]` instead of strided gathers — ~7x on
    /// the sparse search. Costs one extra codebook copy, which the
    /// sparse kernel's 20-100x data savings dwarfs.
    wt: Vec<f32>,
    /// Cached ||w_n||², refreshed together with `wt`.
    w2: Vec<f32>,
    /// Identity of the codebook `wt`/`w2` were hoisted for by
    /// `epoch_begin` (see `codebook_key`); chunk calls with any other
    /// codebook rebuild per call.
    prepared_for: Option<(usize, usize, usize, u64)>,
    /// `epoch_begin`-cache hit/miss counters (see
    /// `TrainingKernel::epoch_cache_stats`).
    cache_hits: u64,
    cache_misses: u64,
    /// Phase B stencil memo (built once per epoch, reused per chunk).
    stencil: StencilCache,
}

impl SparseCpuKernel {
    pub fn new(threads: usize) -> Self {
        SparseCpuKernel {
            threads: threads.max(1),
            wt: Vec::new(),
            w2: Vec::new(),
            prepared_for: None,
            cache_hits: 0,
            cache_misses: 0,
            stencil: StencilCache::new(),
        }
    }

    /// Rebuild the per-epoch codebook caches: ||w||² and the [dim x
    /// nodes] transpose.
    fn prepare(&mut self, codebook: &Codebook) {
        self.w2 = codebook.sq_norms();
        let (dim, nodes) = (codebook.dim, codebook.nodes);
        self.wt.resize(dim * nodes, 0.0);
        for n in 0..nodes {
            let row = codebook.row(n);
            for (c, &v) in row.iter().enumerate() {
                self.wt[c * nodes + n] = v;
            }
        }
    }
}

/// scores[n] += v * col[n] over the whole node axis (auto-vectorizes:
/// single array, scalar broadcast).
#[inline]
fn axpy(scores: &mut [f32], v: f32, col: &[f32]) {
    debug_assert_eq!(scores.len(), col.len());
    for (s, c) in scores.iter_mut().zip(col) {
        *s = c.mul_add(v, *s);
    }
}

impl TrainingKernel for SparseCpuKernel {
    fn name(&self) -> &'static str {
        "sparse-cpu"
    }

    fn epoch_begin(&mut self, codebook: &Codebook) -> anyhow::Result<()> {
        self.prepare(codebook);
        self.prepared_for = Some(crate::kernels::codebook_key(codebook));
        Ok(())
    }

    fn epoch_cache_stats(&self) -> Option<(u64, u64)> {
        Some((self.cache_hits, self.cache_misses))
    }

    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum> {
        let DataShard::Sparse(m) = shard else {
            anyhow::bail!("sparse kernel needs a sparse shard (use -k 0 for dense data)");
        };
        anyhow::ensure!(
            m.cols == codebook.dim,
            "data dim {} != codebook dim {}",
            m.cols,
            codebook.dim
        );

        let key = crate::kernels::codebook_key(codebook);
        if self.prepared_for == Some(key) {
            self.cache_hits += 1;
        } else {
            // Not the epoch_begin codebook: rebuild the caches, and
            // re-key them to the codebook they now describe (leaving the
            // old key would false-hit a later call with the epoch_begin
            // codebook against this call's transpose/norms).
            self.cache_misses += 1;
            self.prepare(codebook);
            self.prepared_for = Some(key);
        }
        let dim = codebook.dim;
        let nodes = codebook.nodes;
        let w2 = &self.w2;
        let wt = &self.wt;

        // --- BMU search, row-parallel over the shared (transposed)
        // codebook: scores[n] = Σ_nz v · wT[c, n], contiguous in n.
        // The argmin over the dense score vector runs through the
        // dispatched microkernel (`simd::argmin_scored`) — bit-identical
        // selection to the historical scalar loop in every SimdKind.
        let kind = simd::dispatch();
        let parts = threadpool::parallel_ranges(m.rows, self.threads, |_, range| {
            let mut bmus = Vec::with_capacity(range.len());
            let mut qe = 0.0f64;
            let mut scores = vec![0.0f32; nodes];
            for r in range {
                let (cols, vals) = m.row(r);
                scores.fill(0.0);
                for (c, v) in cols.iter().zip(vals) {
                    axpy(&mut scores, *v, &wt[*c as usize * nodes..(*c as usize + 1) * nodes]);
                }
                let (best, best_score) = simd::argmin_scored(kind, w2, &scores);
                // ||x||² for QE reconstruction via CsrView::row_sq_norm,
                // computed here inside the row-parallel region (the old
                // serial row_sq_norms() pre-pass allocated a full-shard
                // vector and ran on one thread) — same bits: identical
                // per-row summation order.
                let d2 = (m.row_sq_norm(r) + 2.0 * best_score).max(0.0);
                qe += (d2 as f64).sqrt();
                bmus.push(best);
            }
            (bmus, qe)
        });
        let mut bmus = Vec::with_capacity(m.rows);
        let mut qe_sum = 0.0f64;
        for (b, q) in parts {
            bmus.extend(b);
            qe_sum += q;
        }

        // --- Node-parallel accumulation with sparse axpy.
        let threads = self.threads;
        let (num, den, _) = accumulate_node_parallel_with(
            &AccumConfig {
                rows: m.rows,
                nodes: codebook.nodes,
                dim,
                threads,
                grid,
                neighborhood,
                radius,
                scale,
                mode: SweepMode::Auto,
            },
            &bmus,
            |num_row, r, h| {
                let (cols, vals) = m.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    num_row[*c as usize] += h * v;
                }
            },
            self.stencil.get(grid, neighborhood, radius, scale),
        );

        Ok(EpochAccum {
            bmus,
            num,
            den,
            qe_sum,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_cpu::DenseCpuKernel;
    use crate::som::grid::{GridType, MapType};
    use crate::sparse::Csr;
    use crate::util::rng::Rng;

    /// The defining property: sparse kernel on CSR(X) == dense kernel on X.
    #[test]
    fn matches_dense_kernel_on_same_data() {
        let grid = Grid::new(6, 6, GridType::Square, MapType::Planar);
        let mut rng = Rng::new(10);
        let cb = Codebook::random_init(36, 20, &mut rng);
        let m = Csr::random(50, 20, 0.2, &mut rng);
        let dense = m.to_dense();

        for nb in [
            Neighborhood::gaussian(false),
            Neighborhood::gaussian(true),
            Neighborhood::bubble(),
        ] {
            let got = SparseCpuKernel::new(3)
                .epoch_accumulate(DataShard::Sparse(m.view()), &cb, &grid, nb, 2.0, 0.9)
                .unwrap();
            let want = DenseCpuKernel::new(3)
                .epoch_accumulate(
                    DataShard::Dense {
                        data: &dense,
                        dim: 20,
                    },
                    &cb,
                    &grid,
                    nb,
                    2.0,
                    0.9,
                )
                .unwrap();
            assert_eq!(got.bmus, want.bmus);
            assert!((got.qe_sum - want.qe_sum).abs() < 1e-3);
            for (a, b) in got.num.iter().zip(&want.num) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
            for (a, b) in got.den.iter().zip(&want.den) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn thread_invariance() {
        let grid = Grid::new(4, 4, GridType::Hexagonal, MapType::Toroid);
        let mut rng = Rng::new(11);
        let cb = Codebook::random_init(16, 30, &mut rng);
        let m = Csr::random(40, 30, 0.1, &mut rng);
        let run = |t| {
            SparseCpuKernel::new(t)
                .epoch_accumulate(
                    DataShard::Sparse(m.view()),
                    &cb,
                    &grid,
                    Neighborhood::gaussian(false),
                    1.5,
                    1.0,
                )
                .unwrap()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.bmus, b.bmus);
        assert_eq!(a.num, b.num);
        assert_eq!(a.den, b.den);
    }

    #[test]
    fn rejects_dense_shard() {
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(4, 2);
        let mut k = SparseCpuKernel::new(1);
        assert!(k
            .epoch_accumulate(
                DataShard::Dense {
                    data: &[0.0; 4],
                    dim: 2
                },
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }

    #[test]
    fn empty_rows_map_somewhere_finite() {
        // A row with no nonzeros has distance ||w_n||² to every node: BMU
        // is the smallest-norm node and nothing blows up.
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let mut rng = Rng::new(12);
        let cb = Codebook::random_init(4, 5, &mut rng);
        let m = Csr::new_empty(3, 5);
        let got = SparseCpuKernel::new(2)
            .epoch_accumulate(
                DataShard::Sparse(m.view()),
                &cb,
                &grid,
                Neighborhood::gaussian(false),
                1.0,
                1.0,
            )
            .unwrap();
        let norms = cb.sq_norms();
        let want = norms
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        assert!(got.bmus.iter().all(|&b| b == want));
        assert!(got.qe_sum.is_finite());
    }
}
