//! Dense CPU kernel (paper `-k 0`) — "a straightforward implementation of
//! the batch formulation in Equation 6", parallelized the way §3.1
//! describes:
//!
//!  * BMU search is data-parallel: threads scan disjoint row ranges
//!    against the *shared* codebook (no per-thread codebook copy — the
//!    OpenMP-over-MPI memory saving).
//!  * Accumulation is node-parallel ("the accumulation of local weights
//!    ... is parallelized by an OpenMP directive"): threads own disjoint
//!    node ranges of num/den, so no locks and no duplicated accumulators.
//!  * The neighborhood radius is thresholded (`Neighborhood::cutoff`),
//!    "which translates to speed improvements without compromising the
//!    quality of the trained map" — and once the thresholded window is
//!    smaller than the lattice, Phase B switches to the
//!    [`crate::som::stencil::NeighborhoodStencil`] windowed gather
//!    (O(B·r²·D) instead of O(N·B·D), bit-identical output; see
//!    [`accumulate_node_parallel_ext`]).
//!
//! The BMU inner loop uses the same Gram-trick the GPU kernel exploits:
//! argmin_n ||x||² + ||w_n||² − 2·x·w_n  =  argmin_n (||w_n||²/2 − x·w_n),
//! turning the distance scan into dot products computed by the
//! cache-blocked, runtime-dispatched microkernel in
//! [`crate::kernels::simd`]: 8-row register blocks × L2-resident
//! codebook panels (see [`search_bmus_blocked`]).

use crate::kernels::simd::{self, SimdKind, BLOCK_ROWS};
use crate::kernels::{AccumConfig, AccumStats, DataShard, EpochAccum, SweepMode, TrainingKernel};
use crate::som::{Codebook, Grid, Neighborhood, NeighborhoodStencil, StencilCache};
use crate::util::threadpool;

/// Historical re-export: the scalar dot kernel moved to
/// [`crate::kernels::simd`] with the ISSUE 6 microkernel refactor.
pub use crate::kernels::simd::dot_unrolled;

pub struct DenseCpuKernel {
    pub threads: usize,
    /// Cached ||w_n||² (refreshed in `epoch_begin`, or per call when the
    /// kernel is driven without it).
    w2: Vec<f32>,
    /// Identity of the codebook `w2` was hoisted for by `epoch_begin`
    /// (see `codebook_key`); chunk calls with any other codebook
    /// recompute per call.
    prepared_for: Option<(usize, usize, usize, u64)>,
    /// `epoch_begin`-cache hit/miss counters (see
    /// `TrainingKernel::epoch_cache_stats`).
    cache_hits: u64,
    cache_misses: u64,
    /// Phase B stencil memo: chunked epochs pass identical
    /// (grid, neighborhood, radius, scale) per chunk, so the window
    /// tables are built once per epoch, not once per chunk.
    stencil: StencilCache,
}

impl DenseCpuKernel {
    pub fn new(threads: usize) -> Self {
        DenseCpuKernel {
            threads: threads.max(1),
            w2: Vec::new(),
            prepared_for: None,
            cache_hits: 0,
            cache_misses: 0,
            stencil: StencilCache::new(),
        }
    }

    /// BMU per row + per-row winning squared distance, via the blocked
    /// microkernel with the process-wide dispatched [`SimdKind`] and the
    /// default L2 panel size.
    fn search_bmus(
        &self,
        data: &[f32],
        dim: usize,
        codebook: &Codebook,
        w2: &[f32],
    ) -> (Vec<u32>, Vec<f32>) {
        search_bmus_blocked(
            data,
            dim,
            codebook,
            w2,
            self.threads,
            simd::dispatch(),
            simd::default_panel_nodes(dim),
        )
    }
}

/// Cache-blocked BMU search: per row, the winning node index and the
/// reconstructed squared distance `||x − w_bmu||²` (clamped at 0).
///
/// Loop nest per worker range (§Perf: the search is codebook-bandwidth
/// bound):
///
/// * **panels outer** — the codebook is cut into `panel_nodes`-row
///   N-panels (size them for L2 via [`simd::default_panel_nodes`]); each
///   panel streams from DRAM once per worker range and is then re-read
///   from cache by every row block, instead of the whole N·D codebook
///   streaming once per 8-row block;
/// * **8-row register blocks inner** — [`simd::bmu_scan_panel`] folds a
///   panel into each block's running argmin.
///
/// Per-row argmin state persists across panels, so every row still sees
/// nodes 0..N in ascending order: BMUs, Gram scores, and reconstructed
/// distances are bit-identical to the pre-panel flat scan for the given
/// `kind` (ties to the lowest node index, also across panel boundaries),
/// and independent of both `threads` and `panel_nodes` —
/// `rust/tests/bmu_search_equivalence.rs` pins all of this against a
/// verbatim copy of the pre-refactor search.
///
/// `w2` must hold `||w_n||²` for every node (see `Codebook::sq_norms`).
pub fn search_bmus_blocked(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    w2: &[f32],
    threads: usize,
    kind: SimdKind,
    panel_nodes: usize,
) -> (Vec<u32>, Vec<f32>) {
    assert!(dim > 0 && data.len() % dim == 0, "ragged data buffer");
    assert_eq!(w2.len(), codebook.nodes, "w2 must cover every node");
    let rows = data.len() / dim;
    let nodes = codebook.nodes;
    let panel_nodes = panel_nodes.max(1);
    let parts = threadpool::parallel_ranges(rows, threads, |_, range| {
        let cnt = range.len();
        let mut best = vec![0u32; cnt];
        let mut score = vec![f32::INFINITY; cnt];
        let mut n0 = 0usize;
        while n0 < nodes {
            let n1 = (n0 + panel_nodes).min(nodes);
            let panel = &codebook.weights[n0 * dim..n1 * dim];
            let pw2 = &w2[n0..n1];
            let mut off = 0usize;
            while off < cnt {
                let blen = (cnt - off).min(BLOCK_ROWS);
                let r0 = range.start + off;
                // Lanes blen.. pad with the block's last row; their
                // results are never read back.
                let x: [&[f32]; BLOCK_ROWS] = std::array::from_fn(|k| {
                    let r = r0 + k.min(blen - 1);
                    &data[r * dim..(r + 1) * dim]
                });
                let mut b = [0u32; BLOCK_ROWS];
                let mut s = [f32::INFINITY; BLOCK_ROWS];
                b[..blen].copy_from_slice(&best[off..off + blen]);
                s[..blen].copy_from_slice(&score[off..off + blen]);
                simd::bmu_scan_panel(kind, &x, blen, panel, dim, pw2, n0 as u32, &mut b, &mut s);
                best[off..off + blen].copy_from_slice(&b[..blen]);
                score[off..off + blen].copy_from_slice(&s[..blen]);
                off += blen;
            }
            n0 = n1;
        }
        let dists: Vec<f32> = range
            .clone()
            .zip(&score)
            .map(|(r, &sc)| {
                // Reconstruct the true squared distance for QE. Scalar
                // sequential ||x||² on purpose — the QE bits must not
                // move (golden fixtures and the sparse/dense parity
                // tests pin them).
                let x2: f32 = data[r * dim..(r + 1) * dim].iter().map(|v| v * v).sum();
                (x2 + 2.0 * sc).max(0.0)
            })
            .collect();
        (best, dists)
    });
    let mut bmus = Vec::with_capacity(rows);
    let mut dists = Vec::with_capacity(rows);
    for (b, d) in parts {
        bmus.extend(b);
        dists.extend(d);
    }
    (bmus, dists)
}

/// Node-parallel accumulation in two phases (§Perf: the BMU-histogram
/// formulation, windowed per the paper's §3.1 radius thresholding):
///
///   A. Group rows by their BMU with a **counting sort** (stable, so
///      each BMU's rows stay in ascending row order): X_sum[b] =
///      Σ_{bmu(r)=b} x_r and cnt[b] = |{r : bmu(r)=b}| —
///      `add_row(xsum_row, r, 1.0)` performs the (possibly sparse) add.
///      O(S + N) total; threads own disjoint node ranges and touch only
///      their own buckets, so the sums are lock-free AND deterministic.
///      (The previous formulation had every thread scan all S rows —
///      O(T·S) of redundant filtering that dominated at high thread
///      counts on small chunks.)
///   B. num[n] = Σ_b h(d(b,n)) · X_sum[b], den[n] = Σ_b h · cnt[b],
///      node-parallel, over the *occupied* BMUs only — either as the
///      dense sweep over all B of them, or (when the thresholded radius
///      makes the displacement window smaller than the lattice) as a
///      [`NeighborhoodStencil`] gather that visits only the BMUs whose
///      window reaches the node: O(N·B·D) becomes O(Σ_b window(b)·D) ≈
///      O(B·r²·D). Both iterate contributions in ascending BMU order
///      with table weights equal to the sweep's bit for bit, so `num`,
///      `den` — every output bit — are identical across [`SweepMode`]s
///      and thread counts.
///
/// This is exact up to f32 ordering and turns the O(S·N·D) per-sample
/// update into O(S·D + B·r²·D) with B = occupied nodes ≤ min(S, N): the
/// batch formulation's h depends only on (bmu, node), so rows sharing a
/// BMU share their weight.
pub fn accumulate_node_parallel_ext<F>(
    cfg: &AccumConfig<'_>,
    bmus: &[u32],
    add_row: F,
) -> (Vec<f32>, Vec<f32>, AccumStats)
where
    F: Fn(&mut [f32], usize, f32) + Sync,
{
    match cfg.mode {
        SweepMode::FullSweep => accumulate_node_parallel_with(cfg, bmus, add_row, None),
        SweepMode::Auto if cfg.scale <= 0.0 => {
            // Zero-scale passes short-circuit inside `_with`; don't pay
            // a table build for them.
            accumulate_node_parallel_with(cfg, bmus, add_row, None)
        }
        SweepMode::Auto => {
            let t = std::time::Instant::now();
            let built =
                NeighborhoodStencil::build(cfg.grid, cfg.neighborhood, cfg.radius, cfg.scale);
            let build_time = t.elapsed();
            let (num, den, mut stats) =
                accumulate_node_parallel_with(cfg, bmus, add_row, built.as_ref());
            // The per-pass table construction belongs to Phase B: the
            // stencil must win including its setup cost (kernels
            // amortize it across chunks through a `StencilCache`
            // instead of calling this entry point).
            stats.phase_b += build_time;
            (num, den, stats)
        }
    }
}

/// [`accumulate_node_parallel_ext`] with the Phase B decision already
/// resolved by the caller: `Some` runs the windowed stencil gather,
/// `None` the dense full sweep (`cfg.mode` is ignored). This is the
/// kernels' entry point — they memoize the stencil in a
/// [`crate::som::stencil::StencilCache`] so chunked epochs build the
/// tables once, not once per chunk. The stencil must have been built
/// for exactly this pass's `(grid, neighborhood, radius, scale)`
/// (debug-asserted via [`NeighborhoodStencil::matches`]).
pub fn accumulate_node_parallel_with<F>(
    cfg: &AccumConfig<'_>,
    bmus: &[u32],
    add_row: F,
    stencil: Option<&NeighborhoodStencil>,
) -> (Vec<f32>, Vec<f32>, AccumStats)
where
    F: Fn(&mut [f32], usize, f32) + Sync,
{
    let &AccumConfig {
        rows,
        nodes,
        dim,
        threads,
        grid,
        neighborhood,
        radius,
        scale,
        mode: _,
    } = cfg;
    if let Some(st) = stencil {
        debug_assert!(
            st.matches(grid, neighborhood, radius, scale),
            "stencil was built for different accumulation inputs"
        );
    }
    let cutoff = neighborhood.cutoff(radius);
    debug_assert!(bmus.len() >= rows);
    assert!(rows <= u32::MAX as usize, "shard too large for u32 row ids");

    // scale <= 0 makes every update weight h = w·scale <= 0, which the
    // sweep skips wholesale: both accumulators are exactly zero (the
    // same +0.0 bits the skipping loops would leave). The default
    // `TrainingKernel::project` drives this path once per call, so skip
    // both phases instead of bucketing rows and walking windows to add
    // nothing.
    if scale <= 0.0 {
        return (
            vec![0.0f32; nodes * dim],
            vec![0.0f32; nodes],
            AccumStats {
                phase_a: std::time::Duration::ZERO,
                phase_b: std::time::Duration::ZERO,
                stencil: false,
                active_bmus: 0,
                window_cells: 0,
            },
        );
    }
    let t0 = std::time::Instant::now();

    // --- Phase A: stable counting sort of rows by BMU, then per-BMU
    // sums. `start` is the bucket prefix; `order` holds row ids grouped
    // by BMU, ascending within each bucket — exactly the order the old
    // every-thread-scans-all-rows filter fed `add_row` in, so the f32
    // sums are bit-identical.
    let mut start = vec![0u32; nodes + 1];
    for &b in &bmus[..rows] {
        start[b as usize + 1] += 1;
    }
    for i in 0..nodes {
        start[i + 1] += start[i];
    }
    let mut order = vec![0u32; rows];
    let mut cursor: Vec<u32> = start[..nodes].to_vec();
    for (r, &b) in bmus[..rows].iter().enumerate() {
        let c = &mut cursor[b as usize];
        order[*c as usize] = r as u32;
        *c += 1;
    }
    drop(cursor);

    let mut xsum = vec![0.0f32; nodes * dim];
    let mut cnt = vec![0.0f32; nodes];
    let ranges = threadpool::split_ranges(nodes, threads);
    let xsum_chunks = split_at_ranges(&mut xsum, &ranges, dim);
    let cnt_chunks = split_at_ranges(&mut cnt, &ranges, 1);
    std::thread::scope(|scope| {
        for ((range, xsum_chunk), cnt_chunk) in
            ranges.iter().cloned().zip(xsum_chunks).zip(cnt_chunks)
        {
            let (add_row, order, start) = (&add_row, &order, &start);
            scope.spawn(move || {
                for b in range.clone() {
                    let bucket = &order[start[b] as usize..start[b + 1] as usize];
                    if bucket.is_empty() {
                        continue;
                    }
                    let local = b - range.start;
                    let xrow = &mut xsum_chunk[local * dim..(local + 1) * dim];
                    for &r in bucket {
                        add_row(xrow, r as usize, 1.0);
                        cnt_chunk[local] += 1.0;
                    }
                }
            });
        }
    });
    let phase_a = t0.elapsed();

    // --- Phase B: neighborhood-weighted spread, node-parallel.
    let t1 = std::time::Instant::now();
    let active_bmus;
    let mut num = vec![0.0f32; nodes * dim];
    let mut den = vec![0.0f32; nodes];
    let num_chunks = split_at_ranges(&mut num, &ranges, dim);
    let den_chunks = split_at_ranges(&mut den, &ranges, 1);

    if let Some(st) = stencil {
        // Windowed gather. Active BMUs are indexed per grid row (the
        // row-bucketed index), so each node walks only the sorted active
        // columns inside its window's ascending physical intervals —
        // ascending node order, same summation order as the sweep.
        assert_eq!(
            nodes,
            grid.node_count(),
            "stencil accumulation needs a codebook shaped like the grid"
        );
        let mut row_start = vec![0u32; grid.rows + 1];
        let mut act_cols: Vec<u32> = Vec::new();
        for (b, &c) in cnt.iter().enumerate() {
            if c > 0.0 {
                act_cols.push((b % grid.cols) as u32);
                row_start[b / grid.cols + 1] += 1;
            }
        }
        active_bmus = act_cols.len();
        for i in 0..grid.rows {
            row_start[i + 1] += row_start[i];
        }
        let (xsum, cnt, row_start, act_cols) = (&xsum, &cnt, &row_start, &act_cols);
        std::thread::scope(|scope| {
            for ((range, num_chunk), den_chunk) in
                ranges.iter().cloned().zip(num_chunks).zip(den_chunks)
            {
                scope.spawn(move || {
                    // Lazy stencils (oversized hex per-row tables) get a
                    // per-worker block buffer, refilled only when the
                    // node row changes: node ranges ascend, so a pass
                    // costs ~rows + threads fills in total. Eager
                    // stencils read their precomputed table directly.
                    let lazy = st.is_lazy();
                    let mut block_buf =
                        if lazy { vec![0.0f32; st.window_cells()] } else { Vec::new() };
                    let mut block_row = usize::MAX;
                    for node in range.clone() {
                        let local = node - range.start;
                        let num_row = &mut num_chunk[local * dim..(local + 1) * dim];
                        let (rn, cn) = (node / grid.cols, node % grid.cols);
                        if lazy && rn != block_row {
                            st.fill_row_block(grid, rn, &mut block_buf);
                            block_row = rn;
                        }
                        let col_iv = st.col_intervals(grid, cn);
                        let mut d_acc = 0.0f32;
                        for riv in st.row_intervals(grid, rn).as_slice() {
                            for rb in riv.start..riv.end {
                                let (lo, hi) =
                                    (row_start[rb] as usize, row_start[rb + 1] as usize);
                                if lo == hi {
                                    continue;
                                }
                                let slot_r = riv.slot0 + (rb - riv.start);
                                let trow = if lazy {
                                    st.table_row_in(&block_buf, slot_r)
                                } else {
                                    st.table_row(rn, slot_r)
                                };
                                let acts = &act_cols[lo..hi];
                                for civ in col_iv.as_slice() {
                                    let s = acts
                                        .partition_point(|&c| (c as usize) < civ.start);
                                    for &cb in &acts[s..] {
                                        let cb = cb as usize;
                                        if cb >= civ.end {
                                            break;
                                        }
                                        let h = trow[civ.slot0 + (cb - civ.start)];
                                        if h <= 0.0 {
                                            continue;
                                        }
                                        let b = rb * grid.cols + cb;
                                        d_acc += h * cnt[b];
                                        let src = &xsum[b * dim..(b + 1) * dim];
                                        for (a, s) in num_row.iter_mut().zip(src) {
                                            *a = s.mul_add(h, *a);
                                        }
                                    }
                                }
                            }
                        }
                        den_chunk[local] = d_acc;
                    }
                });
            }
        });
    } else {
        // Dense full sweep over the occupied BMUs (the pre-stencil path,
        // still optimal when the window covers the lattice).
        let active: Vec<u32> = (0..nodes as u32)
            .filter(|&b| cnt[b as usize] > 0.0)
            .collect();
        active_bmus = active.len();
        let (xsum, cnt, active) = (&xsum, &cnt, &active);
        std::thread::scope(|scope| {
            for ((range, num_chunk), den_chunk) in
                ranges.iter().cloned().zip(num_chunks).zip(den_chunks)
            {
                scope.spawn(move || {
                    for node in range.clone() {
                        let local = node - range.start;
                        let num_row = &mut num_chunk[local * dim..(local + 1) * dim];
                        let mut d_acc = 0.0f32;
                        for &b in active {
                            let gd = grid.distance(b as usize, node);
                            if gd > cutoff {
                                continue;
                            }
                            let h = neighborhood.weight(gd, radius) * scale;
                            if h <= 0.0 {
                                continue;
                            }
                            d_acc += h * cnt[b as usize];
                            let src = &xsum[b as usize * dim..(b as usize + 1) * dim];
                            for (a, s) in num_row.iter_mut().zip(src) {
                                *a = s.mul_add(h, *a);
                            }
                        }
                        den_chunk[local] = d_acc;
                    }
                });
            }
        });
    }
    let stats = AccumStats {
        phase_a,
        phase_b: t1.elapsed(),
        stencil: stencil.is_some(),
        active_bmus,
        window_cells: stencil.map_or(0, |s| s.window_cells()),
    };
    (num, den, stats)
}

/// Split a flat buffer into per-range mutable chunks (range i covers
/// `range.len() * width` elements).
fn split_at_ranges<'a>(
    buf: &'a mut [f32],
    ranges: &[std::ops::Range<usize>],
    width: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len() * width);
        out.push(head);
        rest = tail;
    }
    out
}

impl TrainingKernel for DenseCpuKernel {
    fn name(&self) -> &'static str {
        "dense-cpu"
    }

    fn epoch_begin(&mut self, codebook: &Codebook) -> anyhow::Result<()> {
        self.w2 = codebook.sq_norms();
        self.prepared_for = Some(crate::kernels::codebook_key(codebook));
        Ok(())
    }

    fn project(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        _grid: &Grid,
        _neighborhood: Neighborhood,
    ) -> anyhow::Result<Vec<u32>> {
        let DataShard::Dense { data, dim } = shard else {
            anyhow::bail!("dense kernel needs a dense shard (use -k 2 for sparse data)");
        };
        anyhow::ensure!(
            dim == codebook.dim,
            "data dim {dim} != codebook dim {}",
            codebook.dim
        );
        let key = crate::kernels::codebook_key(codebook);
        if self.prepared_for == Some(key) {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
            self.w2 = codebook.sq_norms();
            // Same re-key as epoch_accumulate: the cache must describe
            // the codebook it was built from.
            self.prepared_for = Some(key);
        }
        Ok(self.search_bmus(data, dim, codebook, &self.w2).0)
    }

    fn epoch_cache_stats(&self) -> Option<(u64, u64)> {
        Some((self.cache_hits, self.cache_misses))
    }

    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum> {
        let DataShard::Dense { data, dim } = shard else {
            anyhow::bail!("dense kernel needs a dense shard (use -k 2 for sparse data)");
        };
        anyhow::ensure!(
            dim == codebook.dim,
            "data dim {dim} != codebook dim {}",
            codebook.dim
        );
        let rows = data.len() / dim;

        let key = crate::kernels::codebook_key(codebook);
        if self.prepared_for == Some(key) {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
            self.w2 = codebook.sq_norms();
            // Re-key to the codebook the cache now describes: leaving the
            // old key in place would false-hit a later call that passes
            // the epoch_begin codebook again (stale norms, wrong BMUs).
            self.prepared_for = Some(key);
        }
        let (bmus, dists) = self.search_bmus(data, dim, codebook, &self.w2);
        let qe_sum: f64 = dists.iter().map(|d| (*d as f64).sqrt()).sum();

        let threads = self.threads;
        let (num, den, _) = accumulate_node_parallel_with(
            &AccumConfig {
                rows,
                nodes: codebook.nodes,
                dim,
                threads,
                grid,
                neighborhood,
                radius,
                scale,
                mode: SweepMode::Auto,
            },
            &bmus,
            |num_row, r, h| {
                let x = &data[r * dim..(r + 1) * dim];
                for (acc, v) in num_row.iter_mut().zip(x) {
                    *acc += h * v;
                }
            },
            self.stencil.get(grid, neighborhood, radius, scale),
        );

        Ok(EpochAccum {
            bmus,
            num,
            den,
            qe_sum,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};
    use crate::util::rng::Rng;

    fn setup(nodes_side: usize, dim: usize, rows: usize, seed: u64) -> (Grid, Codebook, Vec<f32>) {
        let grid = Grid::new(nodes_side, nodes_side, GridType::Square, MapType::Planar);
        let mut rng = Rng::new(seed);
        let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        (grid, cb, data)
    }

    /// Naive O(S·N·D) oracle for the full accumulation pass.
    pub fn naive_accumulate(
        data: &[f32],
        dim: usize,
        cb: &Codebook,
        grid: &Grid,
        nb: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> EpochAccum {
        let rows = data.len() / dim;
        let mut acc = EpochAccum::zeros(cb.nodes, dim, rows);
        for r in 0..rows {
            let x = &data[r * dim..(r + 1) * dim];
            let (mut best, mut best_d) = (0usize, f32::INFINITY);
            for n in 0..cb.nodes {
                let d = crate::som::quality::sq_dist(x, cb.row(n));
                if d < best_d {
                    best_d = d;
                    best = n;
                }
            }
            acc.bmus[r] = best as u32;
            acc.qe_sum += (best_d as f64).sqrt();
            for n in 0..cb.nodes {
                let h = nb.weight(grid.distance(best, n), radius) * scale;
                if h > 0.0 {
                    acc.den[n] += h;
                    for d in 0..dim {
                        acc.num[n * dim + d] += h * x[d];
                    }
                }
            }
        }
        acc
    }

    fn assert_accum_close(a: &EpochAccum, b: &EpochAccum, tol: f32) {
        assert_eq!(a.bmus, b.bmus);
        assert!((a.qe_sum - b.qe_sum).abs() < tol as f64 * 10.0);
        for (i, (x, y)) in a.num.iter().zip(&b.num).enumerate() {
            assert!((x - y).abs() < tol, "num[{i}]: {x} vs {y}");
        }
        for (i, (x, y)) in a.den.iter().zip(&b.den).enumerate() {
            assert!((x - y).abs() < tol, "den[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn matches_naive_oracle() {
        let (grid, cb, data) = setup(6, 7, 40, 1);
        let mut k = DenseCpuKernel::new(4);
        let got = k
            .epoch_accumulate(
                DataShard::Dense { data: &data, dim: 7 },
                &cb,
                &grid,
                Neighborhood::gaussian(false),
                2.5,
                0.8,
            )
            .unwrap();
        let want = naive_accumulate(
            &data,
            7,
            &cb,
            &grid,
            Neighborhood::gaussian(false),
            2.5,
            0.8,
        );
        assert_accum_close(&got, &want, 2e-3);
    }

    #[test]
    fn matches_naive_all_variants() {
        for (gt, mt) in [
            (GridType::Square, MapType::Planar),
            (GridType::Square, MapType::Toroid),
            (GridType::Hexagonal, MapType::Planar),
            (GridType::Hexagonal, MapType::Toroid),
        ] {
            for nb in [
                Neighborhood::gaussian(false),
                Neighborhood::gaussian(true),
                Neighborhood::bubble(),
            ] {
                let grid = Grid::new(5, 4, gt, mt);
                let mut rng = Rng::new(7);
                let cb = Codebook::random_init(grid.node_count(), 3, &mut rng);
                let data: Vec<f32> =
                    (0..20 * 3).map(|_| rng.normal_f32()).collect();
                let mut k = DenseCpuKernel::new(3);
                let got = k
                    .epoch_accumulate(
                        DataShard::Dense { data: &data, dim: 3 },
                        &cb,
                        &grid,
                        nb,
                        1.8,
                        1.0,
                    )
                    .unwrap();
                let want = naive_accumulate(&data, 3, &cb, &grid, nb, 1.8, 1.0);
                assert_accum_close(&got, &want, 1e-3);
            }
        }
    }

    #[test]
    fn thread_count_invariant() {
        let (grid, cb, data) = setup(5, 4, 64, 3);
        let run = |threads| {
            DenseCpuKernel::new(threads)
                .epoch_accumulate(
                    DataShard::Dense { data: &data, dim: 4 },
                    &cb,
                    &grid,
                    Neighborhood::gaussian(false),
                    2.0,
                    1.0,
                )
                .unwrap()
        };
        let a = run(1);
        for threads in [2, 4, 8] {
            let b = run(threads);
            assert_eq!(a.bmus, b.bmus);
            // Node-parallel accumulation is deterministic per node: exact.
            assert_eq!(a.num, b.num, "threads={threads}");
            assert_eq!(a.den, b.den, "threads={threads}");
        }
    }

    #[test]
    fn rejects_sparse_shard() {
        let (grid, cb, _) = setup(3, 2, 0, 4);
        let m = crate::sparse::Csr::new_empty(2, 2);
        let mut k = DenseCpuKernel::new(1);
        assert!(k
            .epoch_accumulate(
                DataShard::Sparse(m.view()),
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        let (grid, cb, _) = setup(3, 5, 0, 5);
        let data = vec![0.0; 8];
        let mut k = DenseCpuKernel::new(1);
        assert!(k
            .epoch_accumulate(
                DataShard::Dense { data: &data, dim: 4 },
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }
}
