//! Dense CPU kernel (paper `-k 0`) — "a straightforward implementation of
//! the batch formulation in Equation 6", parallelized the way §3.1
//! describes:
//!
//!  * BMU search is data-parallel: threads scan disjoint row ranges
//!    against the *shared* codebook (no per-thread codebook copy — the
//!    OpenMP-over-MPI memory saving).
//!  * Accumulation is node-parallel ("the accumulation of local weights
//!    ... is parallelized by an OpenMP directive"): threads own disjoint
//!    node ranges of num/den, so no locks and no duplicated accumulators.
//!  * The neighborhood radius is thresholded (`Neighborhood::cutoff`),
//!    "which translates to speed improvements without compromising the
//!    quality of the trained map".
//!
//! The BMU inner loop uses the same Gram-trick the GPU kernel exploits:
//! argmin_n ||x||² + ||w_n||² − 2·x·w_n  =  argmin_n (||w_n||²/2 − x·w_n),
//! turning the distance scan into dot products computed by an 8-row
//! register-blocked FMA microkernel (see §Perf in EXPERIMENTS.md for the
//! measured 13x iteration log on this path).

use crate::kernels::{DataShard, EpochAccum, TrainingKernel};
use crate::som::{Codebook, Grid, Neighborhood};
use crate::util::threadpool;

pub struct DenseCpuKernel {
    pub threads: usize,
    /// Cached ||w_n||² (refreshed in `epoch_begin`, or per call when the
    /// kernel is driven without it).
    w2: Vec<f32>,
    /// Identity of the codebook `w2` was hoisted for by `epoch_begin`
    /// (see `codebook_key`); chunk calls with any other codebook
    /// recompute per call.
    prepared_for: Option<(usize, usize, usize, u64)>,
    /// `epoch_begin`-cache hit/miss counters (see
    /// `TrainingKernel::epoch_cache_stats`).
    cache_hits: u64,
    cache_misses: u64,
}

impl DenseCpuKernel {
    pub fn new(threads: usize) -> Self {
        DenseCpuKernel {
            threads: threads.max(1),
            w2: Vec::new(),
            prepared_for: None,
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// BMU per row + per-row winning squared distance.
    fn search_bmus(
        &self,
        data: &[f32],
        dim: usize,
        codebook: &Codebook,
        w2: &[f32],
    ) -> (Vec<u32>, Vec<f32>) {
        let rows = data.len() / dim;
        let parts = threadpool::parallel_ranges(rows, self.threads, |_, range| {
            let mut bmus = Vec::with_capacity(range.len());
            let mut dists = Vec::with_capacity(range.len());
            // Register-block over 8 rows: each codebook row streams from
            // cache once per 8 data rows (§Perf: the BMU search is
            // codebook-bandwidth bound; 8 rows ≈ the ymm register budget).
            const B: usize = 8;
            let mut it = range.clone().peekable();
            while let Some(r0) = it.next() {
                let mut block = [r0; B];
                let mut blen = 1;
                while blen < B {
                    match it.next() {
                        Some(r) => {
                            block[blen] = r;
                            blen += 1;
                        }
                        None => break,
                    }
                }
                let x: [&[f32]; B] =
                    std::array::from_fn(|k| &data[block[k] * dim..(block[k] + 1) * dim]);
                let mut best = [0u32; B];
                let mut best_score = [f32::INFINITY; B];
                for n in 0..codebook.nodes {
                    let w = codebook.row(n);
                    let half_w2 = 0.5 * w2[n];
                    // score = ||w||²/2 − x·w (argmin-equivalent to the
                    // full squared distance); 8 rows share this w.
                    let dots = dot8(&x, w);
                    for k in 0..blen {
                        let score = half_w2 - dots[k];
                        if score < best_score[k] {
                            best_score[k] = score;
                            best[k] = n as u32;
                        }
                    }
                }
                for k in 0..blen {
                    // Reconstruct the true squared distance for QE.
                    let x2: f32 = x[k].iter().map(|v| v * v).sum();
                    let d2 = (x2 + 2.0 * best_score[k]).max(0.0);
                    bmus.push(best[k]);
                    dists.push(d2);
                }
            }
            (bmus, dists)
        });
        let mut bmus = Vec::with_capacity(rows);
        let mut dists = Vec::with_capacity(rows);
        for (b, d) in parts {
            bmus.extend(b);
            dists.extend(d);
        }
        (bmus, dists)
    }
}

/// Eight dot products against a shared `w`.
///
/// On x86-64 with AVX2+FMA this uses explicit intrinsics: LLVM's
/// auto-vectorizer turns the natural nested loop into cross-row shuffle
/// soup (xmm inserts/shuffles around each FMA — measured 5x off peak),
/// while the intrinsic kernel is 8 packed FMAs + 9 contiguous loads per
/// 8-lane chunk and the shared `w` load amortizes across all rows.
/// Portable scalar fallback elsewhere.
#[inline]
fn dot8(x: &[&[f32]; 8], w: &[f32]) -> [f32; 8] {
    #[cfg(target_arch = "x86_64")]
    {
        // AVX-512 tried and reverted: no gain over AVX2 on this part
        // (single 512-bit FMA unit + downclock) — see EXPERIMENTS.md §Perf.
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            // SAFETY: feature-checked above; slices are read in 8-lane
            // chunks strictly within bounds.
            return unsafe { dot8_avx2(x, w) };
        }
    }
    let mut out = [0.0f32; 8];
    for k in 0..8 {
        out[k] = dot_unrolled(x[k], w);
    }
    out
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot8_avx2(x: &[&[f32]; 8], w: &[f32]) -> [f32; 8] {
    use std::arch::x86_64::*;
    let d = w.len();
    let chunks = d / 8;
    unsafe {
        let mut acc = [_mm256_setzero_ps(); 8];
        let wp = w.as_ptr();
        let xp: [*const f32; 8] = std::array::from_fn(|k| x[k].as_ptr());
        for c in 0..chunks {
            let o = (c * 8) as isize;
            let wv = _mm256_loadu_ps(wp.offset(o));
            for k in 0..8 {
                acc[k] =
                    _mm256_fmadd_ps(_mm256_loadu_ps(xp[k].offset(o)), wv, acc[k]);
            }
        }
        #[inline]
        unsafe fn hsum(v: std::arch::x86_64::__m256) -> f32 {
            unsafe {
                let lo = _mm256_castps256_ps128(v);
                let hi = _mm256_extractf128_ps(v, 1);
                let s = _mm_add_ps(lo, hi);
                let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
                let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
                _mm_cvtss_f32(s)
            }
        }
        let mut out: [f32; 8] = std::array::from_fn(|k| hsum(acc[k]));
        for i in chunks * 8..d {
            for k in 0..8 {
                out[k] = x[k][i].mul_add(w[i], out[k]);
            }
        }
        out
    }
}

/// Dot product with 8 independent accumulators: breaks the sequential
/// FP dependency chain so the compiler vectorizes + pipelines it (§Perf:
/// 4.5x on the BMU search vs the naive single-accumulator loop).
#[inline]
pub fn dot_unrolled(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let chunks = x.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let xb = &x[c * 8..c * 8 + 8];
        let wb = &w[c * 8..c * 8 + 8];
        for k in 0..8 {
            acc[k] = xb[k].mul_add(wb[k], acc[k]);
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..x.len() {
        tail = x[i].mul_add(w[i], tail);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// Node-parallel accumulation shared by the dense and sparse kernels,
/// in two phases (§Perf: the BMU-histogram formulation):
///
///   A. Group rows by their BMU: X_sum[b] = Σ_{bmu(r)=b} x_r and
///      cnt[b] = |{r : bmu(r)=b}| — `add_row(xsum_row, r, 1.0)` performs
///      the (possibly sparse) add; threads own disjoint node ranges so
///      the sums are lock-free AND deterministic (row order per node).
///   B. num[n] = Σ_b h(d(b,n)) · X_sum[b], den[n] = Σ_b h · cnt[b] —
///      node-parallel axpy sweep over the *occupied* BMUs only.
///
/// This is exact up to f32 ordering and turns the O(S·N·D) per-sample
/// update into O(S·D + N·B·D) with B = occupied nodes ≤ min(S, N): the
/// batch formulation's h depends only on (bmu, node), so rows sharing a
/// BMU share their weight. The neighborhood radius is thresholded
/// (`Neighborhood::cutoff`) exactly as §3.1 describes.
pub fn accumulate_node_parallel<F>(
    rows: usize,
    nodes: usize,
    dim: usize,
    threads: usize,
    grid: &Grid,
    neighborhood: Neighborhood,
    radius: f32,
    scale: f32,
    bmus: &[u32],
    add_row: F,
) -> (Vec<f32>, Vec<f32>)
where
    F: Fn(&mut [f32], usize, f32) + Sync,
{
    let cutoff = neighborhood.cutoff(radius);
    debug_assert!(bmus.len() >= rows);

    // --- Phase A: per-BMU sums, threads own disjoint node ranges.
    let mut xsum = vec![0.0f32; nodes * dim];
    let mut cnt = vec![0.0f32; nodes];
    let ranges = threadpool::split_ranges(nodes, threads);
    let xsum_chunks = split_at_ranges(&mut xsum, &ranges, dim);
    let cnt_chunks = split_at_ranges(&mut cnt, &ranges, 1);
    std::thread::scope(|scope| {
        for ((range, xsum_chunk), cnt_chunk) in
            ranges.iter().cloned().zip(xsum_chunks).zip(cnt_chunks)
        {
            let add_row = &add_row;
            let bmus = &bmus[..rows];
            scope.spawn(move || {
                for (r, &bmu) in bmus.iter().enumerate() {
                    let b = bmu as usize;
                    if range.contains(&b) {
                        let local = b - range.start;
                        add_row(
                            &mut xsum_chunk[local * dim..(local + 1) * dim],
                            r,
                            1.0,
                        );
                        cnt_chunk[local] += 1.0;
                    }
                }
            });
        }
    });

    // Occupied BMUs only: B is bounded by min(rows, nodes).
    let active: Vec<u32> = (0..nodes as u32)
        .filter(|&b| cnt[b as usize] > 0.0)
        .collect();

    // --- Phase B: neighborhood-weighted spread, node-parallel.
    let mut num = vec![0.0f32; nodes * dim];
    let mut den = vec![0.0f32; nodes];
    let num_chunks = split_at_ranges(&mut num, &ranges, dim);
    let den_chunks = split_at_ranges(&mut den, &ranges, 1);
    let (xsum, cnt, active) = (&xsum, &cnt, &active);
    std::thread::scope(|scope| {
        for ((range, num_chunk), den_chunk) in
            ranges.iter().cloned().zip(num_chunks).zip(den_chunks)
        {
            scope.spawn(move || {
                for node in range.clone() {
                    let local = node - range.start;
                    let num_row = &mut num_chunk[local * dim..(local + 1) * dim];
                    let mut d_acc = 0.0f32;
                    for &b in active {
                        let gd = grid.distance(b as usize, node);
                        if gd > cutoff {
                            continue;
                        }
                        let h = neighborhood.weight(gd, radius) * scale;
                        if h <= 0.0 {
                            continue;
                        }
                        d_acc += h * cnt[b as usize];
                        let src = &xsum[b as usize * dim..(b as usize + 1) * dim];
                        for (a, s) in num_row.iter_mut().zip(src) {
                            *a = s.mul_add(h, *a);
                        }
                    }
                    den_chunk[local] = d_acc;
                }
            });
        }
    });
    (num, den)
}

/// Split a flat buffer into per-range mutable chunks (range i covers
/// `range.len() * width` elements).
fn split_at_ranges<'a>(
    buf: &'a mut [f32],
    ranges: &[std::ops::Range<usize>],
    width: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len() * width);
        out.push(head);
        rest = tail;
    }
    out
}

impl TrainingKernel for DenseCpuKernel {
    fn name(&self) -> &'static str {
        "dense-cpu"
    }

    fn epoch_begin(&mut self, codebook: &Codebook) -> anyhow::Result<()> {
        self.w2 = codebook.sq_norms();
        self.prepared_for = Some(crate::kernels::codebook_key(codebook));
        Ok(())
    }

    fn project(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        _grid: &Grid,
        _neighborhood: Neighborhood,
    ) -> anyhow::Result<Vec<u32>> {
        let DataShard::Dense { data, dim } = shard else {
            anyhow::bail!("dense kernel needs a dense shard (use -k 2 for sparse data)");
        };
        anyhow::ensure!(
            dim == codebook.dim,
            "data dim {dim} != codebook dim {}",
            codebook.dim
        );
        let key = crate::kernels::codebook_key(codebook);
        if self.prepared_for == Some(key) {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
            self.w2 = codebook.sq_norms();
            // Same re-key as epoch_accumulate: the cache must describe
            // the codebook it was built from.
            self.prepared_for = Some(key);
        }
        Ok(self.search_bmus(data, dim, codebook, &self.w2).0)
    }

    fn epoch_cache_stats(&self) -> Option<(u64, u64)> {
        Some((self.cache_hits, self.cache_misses))
    }

    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum> {
        let DataShard::Dense { data, dim } = shard else {
            anyhow::bail!("dense kernel needs a dense shard (use -k 2 for sparse data)");
        };
        anyhow::ensure!(
            dim == codebook.dim,
            "data dim {dim} != codebook dim {}",
            codebook.dim
        );
        let rows = data.len() / dim;

        let key = crate::kernels::codebook_key(codebook);
        if self.prepared_for == Some(key) {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
            self.w2 = codebook.sq_norms();
            // Re-key to the codebook the cache now describes: leaving the
            // old key in place would false-hit a later call that passes
            // the epoch_begin codebook again (stale norms, wrong BMUs).
            self.prepared_for = Some(key);
        }
        let (bmus, dists) = self.search_bmus(data, dim, codebook, &self.w2);
        let qe_sum: f64 = dists.iter().map(|d| (*d as f64).sqrt()).sum();

        let (num, den) = accumulate_node_parallel(
            rows,
            codebook.nodes,
            dim,
            self.threads,
            grid,
            neighborhood,
            radius,
            scale,
            &bmus,
            |num_row, r, h| {
                let x = &data[r * dim..(r + 1) * dim];
                for (acc, v) in num_row.iter_mut().zip(x) {
                    *acc += h * v;
                }
            },
        );

        Ok(EpochAccum {
            bmus,
            num,
            den,
            qe_sum,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};
    use crate::util::rng::Rng;

    fn setup(nodes_side: usize, dim: usize, rows: usize, seed: u64) -> (Grid, Codebook, Vec<f32>) {
        let grid = Grid::new(nodes_side, nodes_side, GridType::Square, MapType::Planar);
        let mut rng = Rng::new(seed);
        let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        (grid, cb, data)
    }

    /// Naive O(S·N·D) oracle for the full accumulation pass.
    pub fn naive_accumulate(
        data: &[f32],
        dim: usize,
        cb: &Codebook,
        grid: &Grid,
        nb: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> EpochAccum {
        let rows = data.len() / dim;
        let mut acc = EpochAccum::zeros(cb.nodes, dim, rows);
        for r in 0..rows {
            let x = &data[r * dim..(r + 1) * dim];
            let (mut best, mut best_d) = (0usize, f32::INFINITY);
            for n in 0..cb.nodes {
                let d = crate::som::quality::sq_dist(x, cb.row(n));
                if d < best_d {
                    best_d = d;
                    best = n;
                }
            }
            acc.bmus[r] = best as u32;
            acc.qe_sum += (best_d as f64).sqrt();
            for n in 0..cb.nodes {
                let h = nb.weight(grid.distance(best, n), radius) * scale;
                if h > 0.0 {
                    acc.den[n] += h;
                    for d in 0..dim {
                        acc.num[n * dim + d] += h * x[d];
                    }
                }
            }
        }
        acc
    }

    fn assert_accum_close(a: &EpochAccum, b: &EpochAccum, tol: f32) {
        assert_eq!(a.bmus, b.bmus);
        assert!((a.qe_sum - b.qe_sum).abs() < tol as f64 * 10.0);
        for (i, (x, y)) in a.num.iter().zip(&b.num).enumerate() {
            assert!((x - y).abs() < tol, "num[{i}]: {x} vs {y}");
        }
        for (i, (x, y)) in a.den.iter().zip(&b.den).enumerate() {
            assert!((x - y).abs() < tol, "den[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn matches_naive_oracle() {
        let (grid, cb, data) = setup(6, 7, 40, 1);
        let mut k = DenseCpuKernel::new(4);
        let got = k
            .epoch_accumulate(
                DataShard::Dense { data: &data, dim: 7 },
                &cb,
                &grid,
                Neighborhood::gaussian(false),
                2.5,
                0.8,
            )
            .unwrap();
        let want = naive_accumulate(
            &data,
            7,
            &cb,
            &grid,
            Neighborhood::gaussian(false),
            2.5,
            0.8,
        );
        assert_accum_close(&got, &want, 2e-3);
    }

    #[test]
    fn matches_naive_all_variants() {
        for (gt, mt) in [
            (GridType::Square, MapType::Planar),
            (GridType::Square, MapType::Toroid),
            (GridType::Hexagonal, MapType::Planar),
            (GridType::Hexagonal, MapType::Toroid),
        ] {
            for nb in [
                Neighborhood::gaussian(false),
                Neighborhood::gaussian(true),
                Neighborhood::bubble(),
            ] {
                let grid = Grid::new(5, 4, gt, mt);
                let mut rng = Rng::new(7);
                let cb = Codebook::random_init(grid.node_count(), 3, &mut rng);
                let data: Vec<f32> =
                    (0..20 * 3).map(|_| rng.normal_f32()).collect();
                let mut k = DenseCpuKernel::new(3);
                let got = k
                    .epoch_accumulate(
                        DataShard::Dense { data: &data, dim: 3 },
                        &cb,
                        &grid,
                        nb,
                        1.8,
                        1.0,
                    )
                    .unwrap();
                let want = naive_accumulate(&data, 3, &cb, &grid, nb, 1.8, 1.0);
                assert_accum_close(&got, &want, 1e-3);
            }
        }
    }

    #[test]
    fn thread_count_invariant() {
        let (grid, cb, data) = setup(5, 4, 64, 3);
        let run = |threads| {
            DenseCpuKernel::new(threads)
                .epoch_accumulate(
                    DataShard::Dense { data: &data, dim: 4 },
                    &cb,
                    &grid,
                    Neighborhood::gaussian(false),
                    2.0,
                    1.0,
                )
                .unwrap()
        };
        let a = run(1);
        for threads in [2, 4, 8] {
            let b = run(threads);
            assert_eq!(a.bmus, b.bmus);
            // Node-parallel accumulation is deterministic per node: exact.
            assert_eq!(a.num, b.num, "threads={threads}");
            assert_eq!(a.den, b.den, "threads={threads}");
        }
    }

    #[test]
    fn rejects_sparse_shard() {
        let (grid, cb, _) = setup(3, 2, 0, 4);
        let m = crate::sparse::Csr::new_empty(2, 2);
        let mut k = DenseCpuKernel::new(1);
        assert!(k
            .epoch_accumulate(
                DataShard::Sparse(m.view()),
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        let (grid, cb, _) = setup(3, 5, 0, 5);
        let data = vec![0.0; 8];
        let mut k = DenseCpuKernel::new(1);
        assert!(k
            .epoch_accumulate(
                DataShard::Dense { data: &data, dim: 4 },
                &cb,
                &grid,
                Neighborhood::bubble(),
                1.0,
                1.0
            )
            .is_err());
    }
}
