//! Training kernels (paper `-k`): 0 = dense CPU, 1 = accelerator
//! (paper: GPU; here: AOT XLA/PJRT), 2 = sparse CPU.
//!
//! A kernel computes one shard-level batch accumulation pass (the body of
//! `trainOneEpoch`): BMUs, Eq. 6 numerator/denominator, and the
//! quantization-error sum. The coordinator allreduces accumulators across
//! ranks and applies the codebook update.

pub mod accel;
pub mod dense_cpu;
pub mod hybrid;
pub mod sparse_cpu;

use crate::som::{Codebook, Grid, Neighborhood};
use crate::sparse::Csr;

/// Kernel selector, mirroring the paper's `-k NUMBER` (3 = the paper's
/// hybrid accelerator-BMU + CPU-update design, exposed explicitly).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum KernelType {
    DenseCpu,
    Accel,
    SparseCpu,
    Hybrid,
}

impl std::str::FromStr for KernelType {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "0" | "dense" | "dense-cpu" => Ok(KernelType::DenseCpu),
            "1" | "accel" | "gpu" | "xla" => Ok(KernelType::Accel),
            "2" | "sparse" | "sparse-cpu" => Ok(KernelType::SparseCpu),
            "3" | "hybrid" => Ok(KernelType::Hybrid),
            other => Err(format!("unknown kernel type: {other}")),
        }
    }
}

/// A shard of training data, dense or sparse.
#[derive(Copy, Clone, Debug)]
pub enum DataShard<'a> {
    Dense { data: &'a [f32], dim: usize },
    Sparse(&'a Csr),
}

impl<'a> DataShard<'a> {
    pub fn rows(&self) -> usize {
        match self {
            DataShard::Dense { data, dim } => data.len() / dim,
            DataShard::Sparse(m) => m.rows,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            DataShard::Dense { dim, .. } => *dim,
            DataShard::Sparse(m) => m.cols,
        }
    }
}

/// Result of one shard-level accumulation pass.
#[derive(Clone, Debug)]
pub struct EpochAccum {
    /// Best matching unit per shard row.
    pub bmus: Vec<u32>,
    /// Eq. 6 numerator, [nodes x dim] row-major.
    pub num: Vec<f32>,
    /// Eq. 6 denominator, [nodes].
    pub den: Vec<f32>,
    /// Sum of winning Euclidean distances (for QE).
    pub qe_sum: f64,
}

impl EpochAccum {
    pub fn zeros(nodes: usize, dim: usize, rows: usize) -> Self {
        EpochAccum {
            bmus: vec![0; rows],
            num: vec![0.0; nodes * dim],
            den: vec![0.0; nodes],
            qe_sum: 0.0,
        }
    }

    /// Element-wise merge (the allreduce reduction operator).
    pub fn merge(&mut self, other: &EpochAccum) {
        assert_eq!(self.num.len(), other.num.len());
        assert_eq!(self.den.len(), other.den.len());
        for (a, b) in self.num.iter_mut().zip(&other.num) {
            *a += b;
        }
        for (a, b) in self.den.iter_mut().zip(&other.den) {
            *a += b;
        }
        self.qe_sum += other.qe_sum;
    }
}

/// One epoch-step of a training kernel over a shard.
pub trait TrainingKernel {
    /// Human-readable kernel name for reports.
    fn name(&self) -> &'static str;

    /// Compute BMUs + Eq. 6 accumulators for `shard` against `codebook`.
    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_type_parse_matches_cli_numbers() {
        assert_eq!("0".parse::<KernelType>().unwrap(), KernelType::DenseCpu);
        assert_eq!("1".parse::<KernelType>().unwrap(), KernelType::Accel);
        assert_eq!("2".parse::<KernelType>().unwrap(), KernelType::SparseCpu);
        assert_eq!("3".parse::<KernelType>().unwrap(), KernelType::Hybrid);
        assert!("4".parse::<KernelType>().is_err());
    }

    #[test]
    fn accum_merge_adds() {
        let mut a = EpochAccum::zeros(2, 2, 1);
        a.num[0] = 1.0;
        a.den[1] = 2.0;
        a.qe_sum = 1.5;
        let mut b = EpochAccum::zeros(2, 2, 1);
        b.num[0] = 3.0;
        b.den[1] = 4.0;
        b.qe_sum = 0.5;
        a.merge(&b);
        assert_eq!(a.num[0], 4.0);
        assert_eq!(a.den[1], 6.0);
        assert_eq!(a.qe_sum, 2.0);
    }

    #[test]
    fn shard_dims() {
        let d = DataShard::Dense {
            data: &[0.0; 12],
            dim: 3,
        };
        assert_eq!(d.rows(), 4);
        assert_eq!(d.dim(), 3);
    }
}
