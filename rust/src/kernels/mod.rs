//! Training kernels (paper `-k`): 0 = dense CPU, 1 = accelerator
//! (paper: GPU; here: AOT XLA/PJRT), 2 = sparse CPU.
//!
//! A kernel computes one shard-level batch accumulation pass (the body of
//! `trainOneEpoch`): BMUs, Eq. 6 numerator/denominator, and the
//! quantization-error sum. The coordinator allreduces accumulators across
//! ranks and applies the codebook update.
//!
//! Both CPU kernels share one node-parallel accumulator
//! ([`dense_cpu::accumulate_node_parallel_ext`]) whose Phase B picks
//! between a dense full sweep and the windowed stencil gather built on
//! [`crate::som::stencil::NeighborhoodStencil`] — bit-identical outputs,
//! chosen by [`SweepMode`], observable through [`AccumStats`].
//!
//! They also share the cache-blocked, runtime-dispatched BMU-search
//! microkernel in [`simd`] (8-row register blocks × L2-resident codebook
//! panels, scalar / AVX2+FMA resolved once per process).

pub mod accel;
pub mod dense_cpu;
pub mod hybrid;
pub mod simd;
pub mod sparse_cpu;

use crate::som::{Codebook, Grid, Neighborhood};
use crate::sparse::CsrView;

/// Kernel selector, mirroring the paper's `-k NUMBER` (3 = the paper's
/// hybrid accelerator-BMU + CPU-update design, exposed explicitly).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum KernelType {
    DenseCpu,
    Accel,
    SparseCpu,
    Hybrid,
}

impl std::str::FromStr for KernelType {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "0" | "dense" | "dense-cpu" => Ok(KernelType::DenseCpu),
            "1" | "accel" | "gpu" | "xla" => Ok(KernelType::Accel),
            "2" | "sparse" | "sparse-cpu" => Ok(KernelType::SparseCpu),
            "3" | "hybrid" => Ok(KernelType::Hybrid),
            other => Err(format!("unknown kernel type: {other}")),
        }
    }
}

/// Cache key for a codebook: (weights pointer, nodes, dim, sampled
/// content fingerprint). Kernels use it to decide whether an
/// `epoch_begin` cache belongs to the codebook a chunk call passes in.
/// The fingerprint (FNV over ≤64 strided weights) defeats the
/// allocator-reuse trap where a dropped codebook's address is recycled
/// by a new same-shape one: a different codebook then mismatches with
/// overwhelming probability and the kernel falls back to recomputing.
pub(crate) fn codebook_key(cb: &Codebook) -> (usize, usize, usize, u64) {
    let w = &cb.weights;
    let step = (w.len() / 64).max(1);
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
    let mut i = 0;
    while i < w.len() {
        h = (h ^ w[i].to_bits() as u64).wrapping_mul(0x100_0000_01b3);
        i += step;
    }
    (w.as_ptr() as usize, cb.nodes, cb.dim, h)
}

/// Phase B strategy for the shared node-parallel accumulator
/// (`dense_cpu::accumulate_node_parallel_ext`). Both strategies produce
/// **bit-identical** accumulators — the stencil path iterates exactly
/// the contributing BMUs of the full sweep in the same ascending order —
/// so the choice is purely about speed.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum SweepMode {
    /// Windowed stencil gather when the displacement window is smaller
    /// than the lattice, dense full sweep otherwise. What the kernels use.
    #[default]
    Auto,
    /// Always the dense O(N·B·D) sweep over all active BMUs — the
    /// pre-stencil reference path (benches/tests pin it to measure and
    /// verify the stencil against it). There is deliberately no
    /// "force stencil" variant: when the window covers the lattice no
    /// windowed formulation exists, so forcing could only mean Auto.
    FullSweep,
}

/// Per-pass observability from the shared accumulator: wall-clock per
/// phase (feeds `benches/profile_epoch.rs`) and which Phase B strategy
/// actually ran (feeds the equivalence tests).
#[derive(Clone, Debug)]
pub struct AccumStats {
    /// Phase A: counting-sort bucketing + per-BMU sums.
    pub phase_a: std::time::Duration,
    /// Phase B: neighborhood-weighted spread (sweep or stencil gather).
    pub phase_b: std::time::Duration,
    /// True when Phase B ran the windowed stencil gather.
    pub stencil: bool,
    /// Occupied BMUs in this shard (the `B` of the complexity bounds).
    /// Zero-scale passes short-circuit to all-zero output and report 0
    /// here (and zero phase durations) without counting.
    pub active_bmus: usize,
    /// Displacement cells per node gather (0 on the full sweep).
    pub window_cells: usize,
}

/// Geometry + schedule inputs of one accumulation pass, bundled so the
/// extended accumulator keeps a readable signature.
#[derive(Copy, Clone, Debug)]
pub struct AccumConfig<'a> {
    /// Shard rows (`bmus[..rows]` is consumed).
    pub rows: usize,
    /// Codebook nodes; must equal `grid.node_count()`.
    pub nodes: usize,
    /// Data dimension.
    pub dim: usize,
    /// Worker thread budget.
    pub threads: usize,
    /// The neuron lattice.
    pub grid: &'a Grid,
    /// Neighborhood function h(d; r).
    pub neighborhood: Neighborhood,
    /// Current cooling radius.
    pub radius: f32,
    /// Current learning scale.
    pub scale: f32,
    /// Phase B strategy.
    pub mode: SweepMode,
}

/// A shard of training data, dense or sparse. Both variants are *fully
/// borrowed* (a dense slice / a [`CsrView`] of slices), so a shard can
/// point into an owned buffer, a source's reusable scratch, or a
/// memory-mapped file without copying — the zero-copy streaming contract
/// every kernel accepts.
#[derive(Copy, Clone, Debug)]
pub enum DataShard<'a> {
    Dense { data: &'a [f32], dim: usize },
    Sparse(CsrView<'a>),
}

impl<'a> DataShard<'a> {
    pub fn rows(&self) -> usize {
        match self {
            DataShard::Dense { data, dim } => data.len() / dim,
            DataShard::Sparse(m) => m.rows,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            DataShard::Dense { dim, .. } => *dim,
            DataShard::Sparse(m) => m.cols,
        }
    }
}

/// Result of one shard-level accumulation pass.
#[derive(Clone, Debug)]
pub struct EpochAccum {
    /// Best matching unit per shard row.
    pub bmus: Vec<u32>,
    /// Eq. 6 numerator, [nodes x dim] row-major.
    pub num: Vec<f32>,
    /// Eq. 6 denominator, [nodes].
    pub den: Vec<f32>,
    /// Sum of winning Euclidean distances (for QE).
    pub qe_sum: f64,
}

impl EpochAccum {
    pub fn zeros(nodes: usize, dim: usize, rows: usize) -> Self {
        EpochAccum {
            bmus: vec![0; rows],
            num: vec![0.0; nodes * dim],
            den: vec![0.0; nodes],
            qe_sum: 0.0,
        }
    }

    /// Element-wise merge (the allreduce reduction operator).
    pub fn merge(&mut self, other: &EpochAccum) {
        assert_eq!(self.num.len(), other.num.len());
        assert_eq!(self.den.len(), other.den.len());
        for (a, b) in self.num.iter_mut().zip(&other.num) {
            *a += b;
        }
        for (a, b) in self.den.iter_mut().zip(&other.den) {
            *a += b;
        }
        self.qe_sum += other.qe_sum;
    }
}

/// One epoch-step of a training kernel over a shard.
///
/// With the streaming pipeline (io::stream) a `shard` is one bounded
/// *chunk* of the epoch's data: the coordinator calls [`Self::epoch_begin`]
/// once per epoch, then [`Self::epoch_accumulate`] per chunk, merging the
/// partial accumulators with [`EpochAccum::merge`] and concatenating BMUs
/// in chunk order.
/// `Send` is a supertrait: sessions (and the serving daemon's hot maps,
/// which hold one) move between threads with their kernel state inside.
/// Every backend is plain host/device-handle data, so this costs
/// nothing; a future backend with thread-affine state would need a
/// `Send` wrapper anyway to work with the rank threads.
pub trait TrainingKernel: Send {
    /// Human-readable kernel name for reports.
    fn name(&self) -> &'static str;

    /// Hoist per-epoch work (codebook norm caches, transposes, device
    /// uploads) before a chunk loop. The cache is keyed by codebook
    /// identity (buffer pointer + shape + content fingerprint):
    /// `epoch_accumulate` uses it only when called with a matching
    /// codebook, and otherwise rebuilds **and re-keys** the cache to the
    /// codebook it was just built from — so mixing begin-scoped and
    /// begin-less calls (in any interleaving) is safe. One caveat:
    /// mutating the codebook buffer *in place* does not change its
    /// pointer — the fingerprint usually catches it, but do what the
    /// coordinator does and call `epoch_begin` again after every update.
    fn epoch_begin(&mut self, _codebook: &Codebook) -> anyhow::Result<()> {
        Ok(())
    }

    /// Compute BMUs + Eq. 6 accumulators for `shard` against `codebook`.
    fn epoch_accumulate(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> anyhow::Result<EpochAccum>;

    /// BMUs only — the inference path behind
    /// [`crate::session::SomSession::project`]: identical arithmetic
    /// and tie-breaking to `epoch_accumulate`'s search, without
    /// building the Eq. 6 accumulators. The default delegates to a
    /// zero-scale accumulation pass (exact, but pays the grouping
    /// work); kernels with a separable search override it — the dense
    /// CPU kernel serves projection at pure BMU-search cost.
    fn project(
        &mut self,
        shard: DataShard<'_>,
        codebook: &Codebook,
        grid: &Grid,
        neighborhood: Neighborhood,
    ) -> anyhow::Result<Vec<u32>> {
        // Zero scale makes every update weight 0 (and a unit radius
        // keeps the weight arithmetic finite); the accumulators are
        // discarded and the BMUs are exactly the training search's. The
        // caller's real neighborhood is passed through because some
        // kernels (accel) select their device artifact by its kind.
        Ok(self
            .epoch_accumulate(shard, codebook, grid, neighborhood, 1.0, 0.0)?
            .bmus)
    }

    /// Lifetime counters for the `epoch_begin` cache: `(hits, misses)`
    /// across every `epoch_accumulate` call — a *hit* used the hoisted
    /// cache, a *miss* recomputed per call because the codebook did not
    /// match the `epoch_begin` key (`codebook_key`). `None` when the
    /// kernel does not track them (accel/hybrid). This is observability
    /// for the session regression tests: a `SomSession` driving chunked
    /// epochs must never miss, while the legacy kernel-per-call pattern
    /// missed on every chunk.
    fn epoch_cache_stats(&self) -> Option<(u64, u64)> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_type_parse_matches_cli_numbers() {
        assert_eq!("0".parse::<KernelType>().unwrap(), KernelType::DenseCpu);
        assert_eq!("1".parse::<KernelType>().unwrap(), KernelType::Accel);
        assert_eq!("2".parse::<KernelType>().unwrap(), KernelType::SparseCpu);
        assert_eq!("3".parse::<KernelType>().unwrap(), KernelType::Hybrid);
        assert!("4".parse::<KernelType>().is_err());
    }

    #[test]
    fn accum_merge_adds() {
        let mut a = EpochAccum::zeros(2, 2, 1);
        a.num[0] = 1.0;
        a.den[1] = 2.0;
        a.qe_sum = 1.5;
        let mut b = EpochAccum::zeros(2, 2, 1);
        b.num[0] = 3.0;
        b.den[1] = 4.0;
        b.qe_sum = 0.5;
        a.merge(&b);
        assert_eq!(a.num[0], 4.0);
        assert_eq!(a.den[1], 6.0);
        assert_eq!(a.qe_sum, 2.0);
    }

    #[test]
    fn shard_dims() {
        let d = DataShard::Dense {
            data: &[0.0; 12],
            dim: 3,
        };
        assert_eq!(d.rows(), 4);
        assert_eq!(d.dim(), 3);
    }
}
