//! Cross-module property tests (mini-harness in util::prop): format
//! round-trips, schedule/geometry invariants, kernel equivalences and
//! collective algebra under random inputs.

use somoclu::io::stream::{DataSource, InMemorySource};
use somoclu::io::{dense, sparse as sparse_io};
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::sparse_cpu::SparseCpuKernel;
use somoclu::kernels::{DataShard, EpochAccum, TrainingKernel};
use somoclu::prop_assert;
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::prop::{self, Config};
use somoclu::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("somoclu_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn prop_dense_file_round_trip() {
    prop::check_with(
        Config {
            cases: 40,
            ..Default::default()
        },
        "dense-file-roundtrip",
        |g| {
            let rows = g.usize_in(1, 20);
            let cols = g.usize_in(1, 12);
            let header = g.bool();
            let data = g.vec_f32(rows * cols, -1e3, 1e3);
            let path = tmp("rt_dense.txt");
            dense::write_dense(&path, rows, cols, &data, header)
                .map_err(|e| e.to_string())?;
            let m = dense::read_dense(&path).map_err(|e| e.to_string())?;
            prop_assert!(m.rows == rows && m.cols == cols, "shape");
            for (a, b) in m.data.iter().zip(&data) {
                // Text round-trip of f32 Display is exact.
                prop_assert!(a == b, "value {a} != {b}");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_file_round_trip() {
    prop::check_with(
        Config {
            cases: 40,
            ..Default::default()
        },
        "sparse-file-roundtrip",
        |g| {
            let rows = g.usize_in(1, 15);
            let cols = g.usize_in(2, 20);
            let mut rng = Rng::new(g.rng.next_u64());
            let density = 0.3 + 0.5 * g.f32_in(0.0, 1.0) as f64 * 0.5;
            let m = Csr::random(rows, cols, density, &mut rng);
            let path = tmp("rt_sparse.svm");
            sparse_io::write_sparse(&path, &m).map_err(|e| e.to_string())?;
            let rt = sparse_io::read_sparse(&path, cols).map_err(|e| e.to_string())?;
            // Blank (all-zero) rows are dropped by the format; compare
            // the nonempty rows in order.
            let nonempty: Vec<usize> =
                (0..m.rows).filter(|&r| !m.row(r).0.is_empty()).collect();
            prop_assert!(
                rt.rows == nonempty.len(),
                "rows {} vs {}",
                rt.rows,
                nonempty.len()
            );
            for (out_r, &src_r) in nonempty.iter().enumerate() {
                prop_assert!(rt.row(out_r) == m.row(src_r), "row {src_r}");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_dense_kernels_agree() {
    prop::check_with(
        Config {
            cases: 15,
            ..Default::default()
        },
        "kernel-equivalence",
        |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let side = g.usize_in(2, 6);
            let dim = g.usize_in(1, 24);
            let rows = g.usize_in(4, 40);
            let gt = *g.choice(&[GridType::Square, GridType::Hexagonal]);
            let mt = *g.choice(&[MapType::Planar, MapType::Toroid]);
            let nb = *g.choice(&[
                Neighborhood::gaussian(false),
                Neighborhood::gaussian(true),
                Neighborhood::bubble(),
            ]);
            let radius = g.f32_in(0.5, side as f32);
            let grid = Grid::new(side, side, gt, mt);
            let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
            let m = Csr::random(rows, dim, 0.4, &mut rng);
            let dense_data = m.to_dense();

            let a = DenseCpuKernel::new(2)
                .epoch_accumulate(
                    DataShard::Dense {
                        data: &dense_data,
                        dim,
                    },
                    &cb,
                    &grid,
                    nb,
                    radius,
                    0.9,
                )
                .map_err(|e| e.to_string())?;
            let b = SparseCpuKernel::new(2)
                .epoch_accumulate(DataShard::Sparse(m.view()), &cb, &grid, nb, radius, 0.9)
                .map_err(|e| e.to_string())?;
            prop_assert!(a.bmus == b.bmus, "bmus differ");
            for (x, y) in a.num.iter().zip(&b.num) {
                prop_assert!((x - y).abs() < 1e-2, "num {x} vs {y}");
            }
            for (x, y) in a.den.iter().zip(&b.den) {
                prop_assert!((x - y).abs() < 1e-2, "den {x} vs {y}");
            }
            Ok(())
        },
    );
}

/// The coordinator's chunk loop, reproduced standalone: fold every chunk
/// of `source` into one accumulator, reassembling BMUs in chunk order.
fn accumulate_streamed(
    kernel: &mut dyn TrainingKernel,
    source: &mut dyn DataSource,
    cb: &Codebook,
    grid: &Grid,
    nb: Neighborhood,
    radius: f32,
    scale: f32,
) -> Result<EpochAccum, String> {
    kernel.epoch_begin(cb).map_err(|e| e.to_string())?;
    source.reset().map_err(|e| e.to_string())?;
    let mut accum = EpochAccum::zeros(cb.nodes, cb.dim, 0);
    let mut bmus = Vec::with_capacity(source.rows());
    while let Some(chunk) = source.next_chunk().map_err(|e| e.to_string())? {
        let part = kernel
            .epoch_accumulate(chunk, cb, grid, nb, radius, scale)
            .map_err(|e| e.to_string())?;
        bmus.extend_from_slice(&part.bmus);
        accum.merge(&part);
    }
    accum.bmus = bmus;
    Ok(accum)
}

fn accum_close(
    name: &str,
    a: &EpochAccum,
    b: &EpochAccum,
    tol: f32,
) -> Result<(), String> {
    prop_assert!(a.bmus == b.bmus, "{name}: bmus differ");
    prop_assert!(
        (a.qe_sum - b.qe_sum).abs() < 1e-6 * a.qe_sum.abs().max(1.0),
        "{name}: qe {} vs {}",
        a.qe_sum,
        b.qe_sum
    );
    for (i, (x, y)) in a.num.iter().zip(&b.num).enumerate() {
        prop_assert!(
            (x - y).abs() < tol + tol * y.abs(),
            "{name}: num[{i}] {x} vs {y}"
        );
    }
    for (i, (x, y)) in a.den.iter().zip(&b.den).enumerate() {
        prop_assert!(
            (x - y).abs() < tol + tol * y.abs(),
            "{name}: den[{i}] {x} vs {y}"
        );
    }
    Ok(())
}

/// Chunking equivalence: for random rows/dim and chunk sizes {1, 7,
/// rows}, streaming accumulation over an in-memory source equals the
/// whole-shard pass — BMUs bit-for-bit (the BMU of a row depends only on
/// the row and the codebook), accumulators exactly for the single-chunk
/// pass and within f32-reassociation tolerance for real chunking (f32
/// addition is not associative, so regrouped partial sums may differ in
/// the last ulps; the training-level guarantee is the ±1e-4 QE bound).
#[test]
fn prop_chunked_dense_accumulation_matches_whole_shard() {
    prop::check_with(
        Config {
            cases: 20,
            ..Default::default()
        },
        "chunking-equivalence-dense",
        |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let side = g.usize_in(2, 6);
            let dim = g.usize_in(1, 16);
            let rows = g.usize_in(2, 48);
            let radius = g.f32_in(0.5, side as f32);
            let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
            let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
            let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
            let shard = DataShard::Dense { data: &data, dim };
            let nb = Neighborhood::gaussian(false);

            let whole = DenseCpuKernel::new(2)
                .epoch_accumulate(shard, &cb, &grid, nb, radius, 0.9)
                .map_err(|e| e.to_string())?;

            for chunk_rows in [1usize, 7, rows] {
                let mut kernel = DenseCpuKernel::new(2);
                let mut src = InMemorySource::new(shard, chunk_rows);
                let streamed = accumulate_streamed(
                    &mut kernel, &mut src, &cb, &grid, nb, radius, 0.9,
                )?;
                if chunk_rows >= rows {
                    // Single chunk merged into zeros: numerically exact.
                    prop_assert!(streamed.bmus == whole.bmus, "single-chunk bmus");
                    prop_assert!(streamed.num == whole.num, "single-chunk num");
                    prop_assert!(streamed.den == whole.den, "single-chunk den");
                } else {
                    accum_close(
                        &format!("chunk_rows={chunk_rows}"),
                        &streamed,
                        &whole,
                        5e-4,
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chunked_sparse_accumulation_matches_whole_shard() {
    prop::check_with(
        Config {
            cases: 15,
            ..Default::default()
        },
        "chunking-equivalence-sparse",
        |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let side = g.usize_in(2, 5);
            let dim = g.usize_in(2, 20);
            let rows = g.usize_in(2, 40);
            let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
            let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
            let m = Csr::random(rows, dim, 0.3, &mut rng);
            let nb = Neighborhood::gaussian(false);

            let whole = SparseCpuKernel::new(2)
                .epoch_accumulate(DataShard::Sparse(m.view()), &cb, &grid, nb, 1.8, 1.0)
                .map_err(|e| e.to_string())?;
            for chunk_rows in [1usize, 7, rows] {
                let mut kernel = SparseCpuKernel::new(2);
                let mut src = InMemorySource::new(DataShard::Sparse(m.view()), chunk_rows);
                let streamed = accumulate_streamed(
                    &mut kernel, &mut src, &cb, &grid, nb, 1.8, 1.0,
                )?;
                if chunk_rows >= rows {
                    prop_assert!(streamed.bmus == whole.bmus, "single-chunk bmus");
                    prop_assert!(streamed.num == whole.num, "single-chunk num");
                    prop_assert!(streamed.den == whole.den, "single-chunk den");
                } else {
                    accum_close(
                        &format!("chunk_rows={chunk_rows}"),
                        &streamed,
                        &whole,
                        5e-4,
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// `EpochAccum::merge` over random row splits of real kernel output:
/// merging the per-part accumulators must be order-insensitive
/// (commutative + associative up to f32 reassociation) and must agree
/// with the whole-shard pass; concatenated BMUs are exact.
#[test]
fn prop_merge_of_random_splits_matches_whole() {
    prop::check_with(
        Config {
            cases: 15,
            ..Default::default()
        },
        "merge-random-splits",
        |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let dim = g.usize_in(1, 10);
            let rows = g.usize_in(3, 40);
            let grid = Grid::new(4, 4, GridType::Square, MapType::Planar);
            let cb = Codebook::random_init(16, dim, &mut rng);
            let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
            let nb = Neighborhood::gaussian(false);
            let mut kernel = DenseCpuKernel::new(1);

            // Random contiguous split into 2..=4 parts.
            let parts = g.usize_in(2, 4.min(rows));
            let mut cuts = vec![0usize, rows];
            for _ in 0..parts - 1 {
                cuts.push(g.usize_in(1, rows - 1));
            }
            cuts.sort_unstable();
            cuts.dedup();

            let mut accums: Vec<EpochAccum> = Vec::new();
            for w in cuts.windows(2) {
                let part = DataShard::Dense {
                    data: &data[w[0] * dim..w[1] * dim],
                    dim,
                };
                accums.push(
                    kernel
                        .epoch_accumulate(part, &cb, &grid, nb, 2.0, 1.0)
                        .map_err(|e| e.to_string())?,
                );
            }
            let whole = kernel
                .epoch_accumulate(
                    DataShard::Dense { data: &data, dim },
                    &cb,
                    &grid,
                    nb,
                    2.0,
                    1.0,
                )
                .map_err(|e| e.to_string())?;

            // Forward merge == whole (+ BMU concatenation).
            let mut forward = EpochAccum::zeros(cb.nodes, dim, 0);
            let mut bmus = Vec::new();
            for a in &accums {
                bmus.extend_from_slice(&a.bmus);
                forward.merge(a);
            }
            forward.bmus = bmus;
            accum_close("forward", &forward, &whole, 5e-4)?;

            // Reverse merge order: commutativity of the reduction.
            let mut reverse = EpochAccum::zeros(cb.nodes, dim, 0);
            for a in accums.iter().rev() {
                reverse.merge(a);
            }
            for (x, y) in reverse.num.iter().zip(&forward.num) {
                prop_assert!((x - y).abs() < 1e-4, "reverse num {x} vs {y}");
            }
            for (x, y) in reverse.den.iter().zip(&forward.den) {
                prop_assert!((x - y).abs() < 1e-4, "reverse den {x} vs {y}");
            }

            // Tree merge ((a+b)+(c+d)): associativity of the reduction.
            if accums.len() >= 3 {
                let mut left = EpochAccum::zeros(cb.nodes, dim, 0);
                let mut right = EpochAccum::zeros(cb.nodes, dim, 0);
                let mid = accums.len() / 2;
                for a in &accums[..mid] {
                    left.merge(a);
                }
                for a in &accums[mid..] {
                    right.merge(a);
                }
                left.merge(&right);
                for (x, y) in left.num.iter().zip(&forward.num) {
                    prop_assert!((x - y).abs() < 1e-4, "tree num {x} vs {y}");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_accum_merge_commutative_associative() {
    prop::check("merge-algebra", |g| {
        let nodes = g.usize_in(1, 8);
        let dim = g.usize_in(1, 6);
        let mk = |g: &mut prop::Gen| {
            let mut a = EpochAccum::zeros(nodes, dim, 0);
            a.num = g.vec_f32(nodes * dim, -10.0, 10.0);
            a.den = g.vec_f32(nodes, 0.0, 10.0);
            a.qe_sum = g.f32_in(0.0, 100.0) as f64;
            a
        };
        let (a, b, c) = (mk(g), mk(g), mk(g));
        // (a+b)+c == a+(b+c) in f64 qe only approximately; num/den are
        // f32 adds of the same operand orders — compare with tolerance.
        let mut ab = a.clone();
        ab.merge(&b);
        let mut abc1 = ab.clone();
        abc1.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut abc2 = a.clone();
        abc2.merge(&bc);
        for (x, y) in abc1.num.iter().zip(&abc2.num) {
            prop_assert!((x - y).abs() < 1e-4, "assoc num");
        }
        // commutativity
        let mut ba = b.clone();
        ba.merge(&a);
        for (x, y) in ab.num.iter().zip(&ba.num) {
            prop_assert!((x - y).abs() < 1e-4, "comm num");
        }
        Ok(())
    });
}

#[test]
fn prop_umatrix_invariant_under_codebook_translation() {
    // U(j) depends only on differences between codebook vectors: adding
    // a constant vector to every node must not change it.
    prop::check_with(
        Config {
            cases: 30,
            ..Default::default()
        },
        "umatrix-translation",
        |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let side = g.usize_in(2, 7);
            let dim = g.usize_in(1, 8);
            let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
            let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
            let shift = g.vec_f32(dim, -5.0, 5.0);
            let mut cb2 = cb.clone();
            for n in 0..cb2.nodes {
                for (v, s) in cb2.row_mut(n).iter_mut().zip(&shift) {
                    *v += s;
                }
            }
            let u1 = somoclu::som::umatrix::umatrix(&grid, &cb, 1);
            let u2 = somoclu::som::umatrix::umatrix(&grid, &cb2, 1);
            for (a, b) in u1.iter().zip(&u2) {
                prop_assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_training_scale_invariance_of_bmus() {
    // Scaling all data and the codebook by the same positive factor must
    // not change BMU assignments (distances scale uniformly).
    prop::check_with(
        Config {
            cases: 20,
            ..Default::default()
        },
        "bmu-scale-invariance",
        |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let dim = g.usize_in(1, 12);
            let rows = g.usize_in(2, 30);
            let factor = g.f32_in(0.1, 8.0);
            let grid = Grid::new(4, 4, GridType::Square, MapType::Planar);
            let cb = Codebook::random_init(16, dim, &mut rng);
            let data: Vec<f32> =
                (0..rows * dim).map(|_| rng.normal_f32()).collect();

            let mut cb2 = cb.clone();
            for v in cb2.weights.iter_mut() {
                *v *= factor;
            }
            let data2: Vec<f32> = data.iter().map(|v| v * factor).collect();

            let nb = Neighborhood::gaussian(false);
            let a = DenseCpuKernel::new(1)
                .epoch_accumulate(
                    DataShard::Dense { data: &data, dim },
                    &cb,
                    &grid,
                    nb,
                    2.0,
                    1.0,
                )
                .map_err(|e| e.to_string())?;
            let b = DenseCpuKernel::new(1)
                .epoch_accumulate(
                    DataShard::Dense { data: &data2, dim },
                    &cb2,
                    &grid,
                    nb,
                    2.0,
                    1.0,
                )
                .map_err(|e| e.to_string())?;
            prop_assert!(a.bmus == b.bmus, "bmus changed under scaling");
            Ok(())
        },
    );
}
