//! Cross-kernel parity — the equivalence the paper's `-k` switch
//! implies: on identical data (densified for the sparse kernel),
//! `DenseCpu`, `SparseCpu`, and `Hybrid` must produce identical BMUs and
//! Eq. 6 accumulators within 1e-4, both for a single accumulation pass
//! and across a full training run.
//!
//! The hybrid comparison needs the AOT artifacts (`make artifacts`) and a
//! real xla-rs binding; it skips with a message otherwise, exactly like
//! the existing accel integration tests.

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::session::Som;
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::sparse_cpu::SparseCpuKernel;
use somoclu::kernels::{DataShard, EpochAccum, KernelType, TrainingKernel};
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

/// Single-process training through the session API.
fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
}


const TOL: f32 = 1e-4;

fn blob_setup(
    rows: usize,
    dim: usize,
    side: usize,
    seed: u64,
) -> (Grid, Codebook, Vec<f32>, Csr) {
    let mut rng = Rng::new(seed);
    let (dense, _) = data::gaussian_blobs(rows, dim, 4, 0.2, &mut rng);
    let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
    let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
    // Densified-for-sparse: every |v| > 0 entry becomes a CSR nonzero, so
    // both kernels see the same vectors.
    let csr = Csr::from_dense(&dense, rows, dim, 0.0);
    (grid, cb, dense, csr)
}

fn assert_parity(name: &str, a: &EpochAccum, b: &EpochAccum, tol: f32) {
    assert_eq!(a.bmus, b.bmus, "{name}: BMUs diverge");
    assert!(
        (a.qe_sum - b.qe_sum).abs() < tol as f64 * a.bmus.len().max(1) as f64,
        "{name}: qe {} vs {}",
        a.qe_sum,
        b.qe_sum
    );
    for (i, (x, y)) in a.num.iter().zip(&b.num).enumerate() {
        assert!((x - y).abs() < tol, "{name}: num[{i}] {x} vs {y}");
    }
    for (i, (x, y)) in a.den.iter().zip(&b.den).enumerate() {
        assert!((x - y).abs() < tol, "{name}: den[{i}] {x} vs {y}");
    }
}

#[test]
fn dense_and_sparse_accumulate_identically() {
    let (grid, cb, dense, csr) = blob_setup(120, 24, 7, 71);
    for nb in [
        Neighborhood::gaussian(false),
        Neighborhood::gaussian(true),
        Neighborhood::bubble(),
    ] {
        let a = DenseCpuKernel::new(3)
            .epoch_accumulate(
                DataShard::Dense {
                    data: &dense,
                    dim: 24,
                },
                &cb,
                &grid,
                nb,
                3.0,
                0.9,
            )
            .unwrap();
        let b = SparseCpuKernel::new(3)
            .epoch_accumulate(DataShard::Sparse(csr.view()), &cb, &grid, nb, 3.0, 0.9)
            .unwrap();
        assert_parity("dense-vs-sparse", &a, &b, TOL);
    }
}

#[test]
fn dense_and_sparse_full_training_runs_agree() {
    let (_, _, dense, csr) = blob_setup(100, 16, 6, 72);
    let mk = |kernel| TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 6,
        kernel,
        threads: 2,
        radius0: Some(3.0),
        ..Default::default()
    };
    let a = fit(
        &mk(KernelType::DenseCpu),
        DataShard::Dense {
            data: &dense,
            dim: 16,
        },
    )
    .unwrap();
    let b = fit(&mk(KernelType::SparseCpu), DataShard::Sparse(csr.view())).unwrap();
    assert_eq!(a.bmus, b.bmus);
    for (i, (x, y)) in a
        .codebook
        .weights
        .iter()
        .zip(&b.codebook.weights)
        .enumerate()
    {
        assert!((x - y).abs() < TOL, "weights[{i}]: {x} vs {y}");
    }
    assert!(
        (a.final_qe() - b.final_qe()).abs() < TOL as f64,
        "QE {} vs {}",
        a.final_qe(),
        b.final_qe()
    );
}

#[test]
fn epoch_begin_does_not_change_results() {
    // The per-epoch cache hoist (epoch_begin) must be observationally
    // identical to the recompute-per-call path, for both CPU kernels.
    let (grid, cb, dense, csr) = blob_setup(60, 12, 5, 73);
    let nb = Neighborhood::gaussian(false);

    let mut plain = DenseCpuKernel::new(2);
    let without = plain
        .epoch_accumulate(
            DataShard::Dense {
                data: &dense,
                dim: 12,
            },
            &cb,
            &grid,
            nb,
            2.0,
            1.0,
        )
        .unwrap();
    let mut primed = DenseCpuKernel::new(2);
    primed.epoch_begin(&cb).unwrap();
    let with = primed
        .epoch_accumulate(
            DataShard::Dense {
                data: &dense,
                dim: 12,
            },
            &cb,
            &grid,
            nb,
            2.0,
            1.0,
        )
        .unwrap();
    assert_eq!(without.bmus, with.bmus);
    assert_eq!(without.num, with.num);
    assert_eq!(without.den, with.den);

    let mut plain = SparseCpuKernel::new(2);
    let without = plain
        .epoch_accumulate(DataShard::Sparse(csr.view()), &cb, &grid, nb, 2.0, 1.0)
        .unwrap();
    let mut primed = SparseCpuKernel::new(2);
    primed.epoch_begin(&cb).unwrap();
    let with = primed
        .epoch_accumulate(DataShard::Sparse(csr.view()), &cb, &grid, nb, 2.0, 1.0)
        .unwrap();
    assert_eq!(without.bmus, with.bmus);
    assert_eq!(without.num, with.num);
    assert_eq!(without.den, with.den);
}

#[test]
fn epoch_begin_cache_is_keyed_by_codebook_identity() {
    // epoch_begin(cb1) followed by epoch_accumulate(cb2) must not use
    // cb1's hoisted caches: the result has to match a fresh kernel.
    let (grid, cb1, dense, csr) = blob_setup(50, 8, 5, 75);
    let mut rng = Rng::new(76);
    let cb2 = Codebook::random_init(grid.node_count(), 8, &mut rng);
    let nb = Neighborhood::gaussian(false);

    let mut stale = DenseCpuKernel::new(2);
    stale.epoch_begin(&cb1).unwrap();
    let got = stale
        .epoch_accumulate(
            DataShard::Dense {
                data: &dense,
                dim: 8,
            },
            &cb2,
            &grid,
            nb,
            2.0,
            1.0,
        )
        .unwrap();
    let want = DenseCpuKernel::new(2)
        .epoch_accumulate(
            DataShard::Dense {
                data: &dense,
                dim: 8,
            },
            &cb2,
            &grid,
            nb,
            2.0,
            1.0,
        )
        .unwrap();
    assert_eq!(got.bmus, want.bmus);
    assert_eq!(got.num, want.num);

    let mut stale = SparseCpuKernel::new(2);
    stale.epoch_begin(&cb1).unwrap();
    let got = stale
        .epoch_accumulate(DataShard::Sparse(csr.view()), &cb2, &grid, nb, 2.0, 1.0)
        .unwrap();
    let want = SparseCpuKernel::new(2)
        .epoch_accumulate(DataShard::Sparse(csr.view()), &cb2, &grid, nb, 2.0, 1.0)
        .unwrap();
    assert_eq!(got.bmus, want.bmus);
    assert_eq!(got.num, want.num);
}

/// Hybrid (accel BMU + CPU update) against the dense CPU kernel. Needs
/// AOT artifacts and a real PJRT binding; skips otherwise.
#[test]
fn hybrid_parity_with_cpu_kernels() {
    if !somoclu::runtime::Manifest::default_dir()
        .join("manifest.json")
        .exists()
    {
        eprintln!("skipping: run `make artifacts` (and link real xla-rs) first");
        return;
    }
    let (grid, cb, dense, csr) = blob_setup(90, 10, 6, 74);
    let nb = Neighborhood::gaussian(false);
    let want = DenseCpuKernel::new(2)
        .epoch_accumulate(
            DataShard::Dense {
                data: &dense,
                dim: 10,
            },
            &cb,
            &grid,
            nb,
            2.5,
            0.8,
        )
        .unwrap();
    let sparse = SparseCpuKernel::new(2)
        .epoch_accumulate(DataShard::Sparse(csr.view()), &cb, &grid, nb, 2.5, 0.8)
        .unwrap();
    assert_parity("dense-vs-sparse", &want, &sparse, TOL);

    let mut hybrid = match somoclu::kernels::hybrid::HybridKernel::from_env(2) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("skipping hybrid parity: {e:#}");
            return;
        }
    };
    let got = hybrid
        .epoch_accumulate(
            DataShard::Dense {
                data: &dense,
                dim: 10,
            },
            &cb,
            &grid,
            nb,
            2.5,
            0.8,
        )
        .unwrap();
    assert_parity("hybrid-vs-dense", &got, &want, TOL);
}
