//! End-to-end out-of-core streaming (`--chunk-rows` / `fit_source`):
//!
//! * a chunked file-backed run must reproduce the in-memory run — same
//!   final QE (±1e-4) and identical BMUs;
//! * the CLI accepts `--chunk-rows` and produces the same artifacts.
//!
//! The bounded-memory acceptance property lives in its own binary
//! (`stream_bounded.rs`) because the data-buffer gauge is process-global.

use std::process::Command;

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::io::stream::DataSource;
use somoclu::session::Som;
use somoclu::io::stream::{ChunkedDenseFileSource, ChunkedSparseFileSource};
use somoclu::io::{dense, sparse as sparse_io};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("somoclu_streaming_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
}

fn fit_source(
    cfg: &TrainConfig,
    source: &mut dyn DataSource,
) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_source(source)
}

fn small_cfg(kernel: KernelType) -> TrainConfig {
    TrainConfig {
        rows: 8,
        cols: 8,
        epochs: 6,
        kernel,
        threads: 2,
        radius0: Some(4.0),
        ..Default::default()
    }
}

#[test]
fn dense_file_stream_matches_in_memory_run() {
    let dir = tmpdir("dense_eq");
    let mut rng = Rng::new(600);
    let (rows, dim) = (500, 12);
    let (data, _) = data::gaussian_blobs(rows, dim, 5, 0.2, &mut rng);
    let path = dir.join("data.txt");
    dense::write_dense(&path, rows, dim, &data, false).unwrap();

    let cfg = small_cfg(KernelType::DenseCpu);
    let resident = fit(&cfg, DataShard::Dense { data: &data, dim }).unwrap();

    for chunk_rows in [37usize, 100, 1000] {
        let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
        let streamed = fit_source(&cfg, &mut src).unwrap();
        assert_eq!(streamed.bmus, resident.bmus, "chunk_rows={chunk_rows}");
        assert!(
            (streamed.final_qe() - resident.final_qe()).abs() < 1e-4,
            "chunk_rows={chunk_rows}: QE {} vs {}",
            streamed.final_qe(),
            resident.final_qe()
        );
        // Per-epoch QE trajectories agree too, not just the endpoint.
        for (a, b) in streamed.epochs.iter().zip(&resident.epochs) {
            assert!(
                (a.qe - b.qe).abs() < 1e-4,
                "epoch {}: {} vs {}",
                a.epoch,
                a.qe,
                b.qe
            );
        }
    }
}

#[test]
fn sparse_file_stream_matches_in_memory_run() {
    let dir = tmpdir("sparse_eq");
    let mut rng = Rng::new(601);
    let m = Csr::random(300, 64, 0.08, &mut rng);
    let path = dir.join("data.svm");
    sparse_io::write_sparse(&path, &m).unwrap();
    // Re-read so blank-row semantics match the file exactly.
    let resident_m = sparse_io::read_sparse(&path, 64).unwrap();

    let cfg = small_cfg(KernelType::SparseCpu);
    let resident = fit(&cfg, DataShard::Sparse(resident_m.view())).unwrap();

    for chunk_rows in [23usize, 300] {
        let mut src = ChunkedSparseFileSource::open(&path, 64, chunk_rows).unwrap();
        let streamed = fit_source(&cfg, &mut src).unwrap();
        assert_eq!(streamed.bmus, resident.bmus, "chunk_rows={chunk_rows}");
        assert!(
            (streamed.final_qe() - resident.final_qe()).abs() < 1e-4,
            "chunk_rows={chunk_rows}"
        );
    }
}

#[test]
fn cli_chunk_rows_matches_in_memory_cli_run() {
    let dir = tmpdir("cli");
    let mut rng = Rng::new(602);
    let (rows, dim) = (160, 6);
    let (d, _) = data::gaussian_blobs(rows, dim, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, rows, dim, &d, false).unwrap();

    let bin = env!("CARGO_BIN_EXE_somoclu");
    let run = |prefix: &str, extra: &[&str]| {
        let out_prefix = dir.join(prefix);
        let mut args: Vec<String> = ["-e", "3", "-x", "8", "-y", "8", "-r", "4", "--seed", "7"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        args.extend(extra.iter().map(|s| s.to_string()));
        args.push(input.to_str().unwrap().to_string());
        args.push(out_prefix.to_str().unwrap().to_string());
        let out = Command::new(bin).args(&args).output().unwrap();
        assert!(
            out.status.success(),
            "stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        dense::read_dense(format!("{}.wts", out_prefix.display())).unwrap()
    };

    let resident = run("mem", &[]);
    let streamed = run("stream", &["--chunk-rows", "50"]);
    assert_eq!(resident.rows, streamed.rows);
    for (a, b) in resident.data.iter().zip(&streamed.data) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}
