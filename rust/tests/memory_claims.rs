//! The paper's memory claims, verified with the tracking allocator:
//!
//! * §3.1: OpenMP threads share one codebook; MPI processes each copy it
//!   — "a minimum fifty per cent reduction in memory even when only two
//!   threads are used" (CLAIM-MEM50).
//! * §5.1: "the sparse kernel using only twenty per cent of the memory of
//!   the dense one" at 5% density (CLAIM-SPARSE-MEM).
//! * Fig. 7: zero-copy (Python-style) interface adds ~nothing; the
//!   converting (R/MATLAB-style) interface duplicates the data.

use somoclu::api::DataInput;
use somoclu::cluster::netmodel::NetModel;
use somoclu::cluster::runner::ClusterData;
use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::session::Som;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::sparse::Csr;
use somoclu::util::memtrack::MemRegion;
use somoclu::util::rng::Rng;

fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
}

fn cfg() -> TrainConfig {
    TrainConfig {
        rows: 12,
        cols: 12,
        epochs: 2,
        threads: 2,
        ranks: 1,
        radius0: Some(6.0),
        ..Default::default()
    }
}

/// Threads share the codebook; simulated ranks duplicate it — with the
/// same total parallelism the rank path must hold >= 2 codebook copies.
#[test]
fn threads_share_codebook_ranks_duplicate_it() {
    let mut rng = Rng::new(400);
    // Small data, biggish codebook so the codebook dominates.
    let dim = 256;
    let (d, _) = data::gaussian_blobs(64, dim, 2, 0.3, &mut rng);
    let codebook_bytes = 12 * 12 * dim * 4;

    // Two threads, one process (shared codebook).
    let threaded = {
        let mut c = cfg();
        c.threads = 2;
        let region = MemRegion::start();
        let _ = fit(&c, DataShard::Dense { data: &d, dim }).unwrap();
        region.peak_delta()
    };

    // Two ranks, one thread each (duplicated codebook + reduce buffers).
    let ranked = {
        let mut c = cfg();
        c.threads = 1;
        c.ranks = 2;
        let region = MemRegion::start();
        let _ = Som::builder()
            .config(c.clone())
            .net(NetModel::ideal())
            .build()
            .unwrap()
            .fit_cluster(ClusterData::Dense {
                data: d.clone(),
                dim,
            })
            .unwrap();
        region.peak_delta()
    };

    // The rank path must cost at least one extra codebook worth of peak
    // memory over the threaded path.
    assert!(
        ranked >= threaded + codebook_bytes / 2,
        "ranked {ranked} vs threaded {threaded} (codebook {codebook_bytes})"
    );
}

/// 5%-dense data: the CSR representation must be a small fraction of the
/// dense buffer (the paper reports 20% end-to-end at 100k instances;
/// representation-level the gap is larger).
#[test]
fn sparse_representation_saves_memory() {
    let mut rng = Rng::new(401);
    let (rows, dim) = (2000, 1000);
    let m = Csr::random(rows, dim, 0.05, &mut rng);
    let dense_bytes = rows * dim * 4;
    let sparse_bytes = m.heap_bytes();
    let ratio = sparse_bytes as f64 / dense_bytes as f64;
    assert!(
        ratio < 0.25,
        "sparse rep is {ratio:.2} of dense ({sparse_bytes} vs {dense_bytes})"
    );
}

/// End-to-end peak memory: sparse training holds CSR + codebook; dense
/// training holds the dense matrix + codebook.
#[test]
fn sparse_training_peak_below_dense() {
    let mut rng = Rng::new(402);
    let (rows, dim) = (1500, 512);
    let m = Csr::random(rows, dim, 0.05, &mut rng);
    let dense = m.to_dense();

    let mut dense_cfg = cfg();
    dense_cfg.kernel = KernelType::DenseCpu;
    let mut sparse_cfg = cfg();
    sparse_cfg.kernel = KernelType::SparseCpu;

    let region = MemRegion::start();
    let _ = fit(&dense_cfg, DataShard::Dense { data: &dense, dim }).unwrap();
    let dense_peak = region.peak_delta();

    let region = MemRegion::start();
    let _ = fit(&sparse_cfg, DataShard::Sparse(m.view())).unwrap();
    let sparse_peak = region.peak_delta();

    // The dense input buffer itself isn't counted in either region (it
    // pre-exists), so compare *total working set*: sparse path peak plus
    // its input vs dense path peak plus its input.
    let dense_total = dense_peak + dense.len() * 4;
    let sparse_total = sparse_peak + m.heap_bytes();
    assert!(
        (sparse_total as f64) < 0.8 * dense_total as f64,
        "sparse {sparse_total} vs dense {dense_total}"
    );
}

/// Fig. 7 mechanism: the converting (f64 -> f32) interface allocates a
/// full extra copy of the data; the borrowed interface does not.
#[test]
fn converting_interface_duplicates_data() {
    let mut rng = Rng::new(403);
    let dim = 64;
    let (d, _) = data::gaussian_blobs(2000, dim, 3, 0.3, &mut rng);
    let d64: Vec<f64> = d.iter().map(|&v| v as f64).collect();
    let data_f32_bytes = d.len() * 4;

    let c = cfg();
    let region = MemRegion::start();
    let _ = Som::builder()
        .config(c.clone())
        .build()
        .unwrap()
        .fit(DataInput::BorrowedF32 { data: &d, dim })
        .unwrap();
    let borrowed_peak = region.peak_delta();

    let region = MemRegion::start();
    let _ = Som::builder()
        .config(c.clone())
        .build()
        .unwrap()
        .fit(DataInput::ConvertedF64 { data: &d64, dim })
        .unwrap();
    let converted_peak = region.peak_delta();

    assert!(
        converted_peak >= borrowed_peak + data_f32_bytes * 9 / 10,
        "converted {converted_peak} vs borrowed {borrowed_peak} \
         (data copy {data_f32_bytes})"
    );
}
