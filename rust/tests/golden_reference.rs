//! Golden-reference replay against the Python/JAX oracle.
//!
//! Fixtures in `rust/tests/fixtures/golden_*` were produced by
//! `python/compile/gen_golden.py` running the pure-jnp oracle
//! (`python/compile/kernels/ref.py`) for a pinned configuration (see
//! `golden_meta.json`): 6x6 square/planar map, gaussian neighborhood,
//! linear radius 3->1 and scale 1->0.01 over 3 epochs, 64x5 blob data,
//! fixed initial codebook. The Rust trainer must reproduce the QE
//! trajectory, the final codebook and the final-epoch BMUs — the
//! cross-layer anchor tying the Rust kernels to the Eq. 2/5/6 oracle.
//!
//! The generator self-checks that the oracle's direct-distance argmin and
//! the Rust kernels' Gram-trick argmin agree on every BMU of the run, so
//! these comparisons sit safely away from argmin ties.

use std::path::PathBuf;

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::io::read_dense;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::session::Som;
use somoclu::som::Codebook;

/// Training through the session API, warm-started from the golden
/// fixture's initial codebook.
fn fit_from(
    cfg: &TrainConfig,
    shard: DataShard<'_>,
    init: Codebook,
) -> anyhow::Result<TrainResult> {
    Som::builder()
        .config(cfg.clone())
        .initial_codebook(init)
        .build()?
        .fit_shard(shard)
}

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(name)
}

fn golden_cfg(kernel: KernelType) -> TrainConfig {
    TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 3,
        kernel,
        threads: 2,
        radius0: Some(3.0),
        radius_n: 1.0,
        scale0: 1.0,
        scale_n: 0.01,
        ..Default::default()
    }
}

struct Golden {
    data: Vec<f32>,
    dim: usize,
    rows: usize,
    init: Codebook,
    expected_cb: Vec<f32>,
    expected_qe: Vec<f64>,
    expected_bmus: Vec<u32>,
}

fn load_golden() -> Golden {
    let data = read_dense(fixture("golden_data.txt")).unwrap();
    let init = read_dense(fixture("golden_init_codebook.txt")).unwrap();
    let expected_cb = read_dense(fixture("golden_codebook_after3.txt")).unwrap();
    assert_eq!((init.rows, init.cols), (36, data.cols));
    assert_eq!((expected_cb.rows, expected_cb.cols), (36, data.cols));
    let expected_qe: Vec<f64> = std::fs::read_to_string(fixture("golden_qe.txt"))
        .unwrap()
        .lines()
        .map(|l| l.trim().parse().unwrap())
        .collect();
    let expected_bmus: Vec<u32> = std::fs::read_to_string(fixture("golden_bmus.txt"))
        .unwrap()
        .lines()
        .map(|l| l.trim().parse().unwrap())
        .collect();
    assert_eq!(expected_qe.len(), 3);
    assert_eq!(expected_bmus.len(), data.rows);
    Golden {
        dim: data.cols,
        rows: data.rows,
        data: data.data,
        init: Codebook {
            nodes: init.rows,
            dim: init.cols,
            weights: init.data,
        },
        expected_cb: expected_cb.data,
        expected_qe,
        expected_bmus,
    }
}

fn check_against_golden(g: &Golden, res: &somoclu::coordinator::train::TrainResult) {
    assert_eq!(res.bmus, g.expected_bmus, "final-epoch BMUs diverge from oracle");
    for (epoch, (got, want)) in res
        .epochs
        .iter()
        .map(|e| e.qe)
        .zip(&g.expected_qe)
        .enumerate()
    {
        assert!(
            (got - want).abs() < 1e-4,
            "epoch {epoch}: QE {got} vs oracle {want}"
        );
    }
    for (i, (a, b)) in res
        .codebook
        .weights
        .iter()
        .zip(&g.expected_cb)
        .enumerate()
    {
        assert!(
            (a - b).abs() < 1e-3,
            "codebook[{i}]: {a} vs oracle {b}"
        );
    }
}

#[test]
fn dense_kernel_matches_python_oracle() {
    let g = load_golden();
    let res = fit_from(
        &golden_cfg(KernelType::DenseCpu),
        DataShard::Dense {
            data: &g.data,
            dim: g.dim,
        },
        g.init.clone(),
    )
    .unwrap();
    check_against_golden(&g, &res);
}

#[test]
fn sparse_kernel_matches_python_oracle() {
    // The same trajectory through the sparse kernel on densified CSR —
    // ties the `-k 2` path to the oracle as well.
    let g = load_golden();
    let m = somoclu::sparse::Csr::from_dense(&g.data, g.rows, g.dim, 0.0);
    let res = fit_from(
        &golden_cfg(KernelType::SparseCpu),
        DataShard::Sparse(m.view()),
        g.init.clone(),
    )
    .unwrap();
    check_against_golden(&g, &res);
}

#[test]
fn chunked_run_matches_python_oracle() {
    // Streaming must not move the trajectory either: chunked accumulation
    // lands on the same golden outputs.
    let g = load_golden();
    for chunk_rows in [1usize, 7] {
        let cfg = TrainConfig {
            chunk_rows,
            ..golden_cfg(KernelType::DenseCpu)
        };
        let res = fit_from(
            &cfg,
            DataShard::Dense {
                data: &g.data,
                dim: g.dim,
            },
            g.init.clone(),
        )
        .unwrap();
        check_against_golden(&g, &res);
    }
}
