//! End-to-end through the real binary: write input files, invoke the
//! `somoclu` CLI exactly as the paper's examples do, check outputs.

use std::path::PathBuf;
use std::process::Command;

use somoclu::data;
use somoclu::io::{dense, read_dense, sparse as sparse_io};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn bin() -> PathBuf {
    // target/<profile>/somoclu next to the test executable.
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("somoclu");
    p
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("somoclu_cli_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn paper_basic_invocation() {
    // "$ Somoclu data/rgbs.txt data/rgbs" — scaled-down map for speed.
    let dir = tmpdir("basic");
    let mut rng = Rng::new(500);
    let (d, _) = data::rgb_toy(120, &mut rng);
    let input = dir.join("rgbs.txt");
    dense::write_dense(&input, 120, 3, &d, false).unwrap();
    let prefix = dir.join("rgbs");

    let out = Command::new(bin())
        .args([
            "-e", "4", "-x", "8", "-y", "8", "-r", "4",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    for ext in [".wts", ".bm", ".umx"] {
        let p = format!("{}{ext}", prefix.display());
        assert!(std::path::Path::new(&p).exists(), "{p}");
    }
    let wts = read_dense(format!("{}.wts", prefix.display())).unwrap();
    assert_eq!((wts.rows, wts.cols), (64, 3));
}

#[test]
fn paper_cluster_invocation() {
    // "mpirun -np 4 Somoclu -k 0 --rows 20 --columns 20 ..." with
    // --ranks standing in for mpirun.
    let dir = tmpdir("cluster");
    let mut rng = Rng::new(501);
    let (d, _) = data::gaussian_blobs(160, 6, 4, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 160, 6, &d, false).unwrap();
    let prefix = dir.join("out");

    let out = Command::new(bin())
        .args([
            "--ranks", "4", "-k", "0", "--rows", "10", "--columns", "10",
            "-e", "4", "-r", "5", "-v",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("cluster: 4 ranks"), "{stderr}");
    assert!(stderr.contains("epoch"), "{stderr}");
}

#[test]
fn sparse_kernel_invocation() {
    let dir = tmpdir("sparse");
    let mut rng = Rng::new(502);
    let m = Csr::random(100, 50, 0.1, &mut rng);
    let input = dir.join("data.svm");
    sparse_io::write_sparse(&input, &m).unwrap();
    let prefix = dir.join("out");

    let out = Command::new(bin())
        .args([
            "-k", "2", "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("sparse input"), "{stderr}");
}

#[test]
fn train_help_lists_paper_flags() {
    let out = Command::new(bin()).args(["train", "--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for flag in [
        "-c", "-e", "-g", "-k", "-m", "-n", "-p", "-t", "-r", "-R", "-T",
        "-l", "-L", "-s", "-x", "-y", "--ranks", "--keep-last",
        "INPUT_FILE", "OUTPUT_PREFIX",
    ] {
        assert!(text.contains(flag), "missing {flag} in:\n{text}");
    }
}

#[test]
fn top_level_help_lists_subcommands() {
    for invocation in [vec!["--help"], vec!["help"], vec![]] {
        let out = Command::new(bin()).args(&invocation).output().unwrap();
        assert!(out.status.success(), "{invocation:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        for needle in [
            "Usage", "somoclu train", "somoclu serve", "somoclu ensemble",
            "somoclu quality", "somoclu convert", "somoclu info",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }
}

#[test]
fn per_subcommand_help_screens() {
    let out = Command::new(bin()).args(["serve", "--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["LISTEN_ADDR", "--checkpoint", "--state-dir"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }

    let out = Command::new(bin()).args(["convert", "--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("--sparse"), "{text}");

    let out = Command::new(bin()).args(["ensemble", "--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "--members", "--clusters", "--seed", "--kmeans-iters",
        "--checkpoint-every", "INPUT_FILE", "OUTPUT_PREFIX",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }

    let out = Command::new(bin()).args(["quality", "--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["--knn", "--planes", "--out", "CHECKPOINT", "DATA_FILE"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

#[test]
fn quality_subcommand_emits_versioned_json() {
    // Schema-level check only; the bit-exact QE/TE cross-validation
    // against the library lives in rust/tests/ensemble_quality.rs.
    let dir = tmpdir("quality_schema");
    let mut rng = Rng::new(508);
    let (d, _) = data::gaussian_blobs(40, 3, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 40, 3, &d, false).unwrap();
    let prefix = dir.join("map");
    let out = Command::new(bin())
        .args([
            "train", "-e", "3", "-x", "5", "-y", "5", "-r", "2",
            "--checkpoint-every", "3",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ckpt = format!("{}.epoch3.somc", prefix.display());

    let out = Command::new(bin())
        .args(["quality", "-k", "4", &ckpt, input.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = somoclu::util::json::Json::parse(&String::from_utf8_lossy(&out.stdout))
        .expect("stdout is valid JSON");
    use somoclu::util::json::Json;
    assert_eq!(json.get("version").and_then(Json::as_usize), Some(1));
    for key in [
        "qe", "te", "trustworthiness", "neighborhood_preservation", "knn",
        "rows", "dim", "map", "component_planes", "umatrix",
    ] {
        assert!(json.get(key).is_some(), "missing key {key}");
    }
    assert_eq!(json.get("rows").and_then(Json::as_usize), Some(40));
    assert_eq!(
        json.get("map").and_then(|m| m.get("grid")).and_then(Json::as_str),
        Some("square")
    );
}

#[test]
fn flat_invocation_is_deprecated_train_alias() {
    // `somoclu train ...` and the pre-subcommand flat form produce
    // byte-identical outputs; only the flat form warns on stderr.
    let dir = tmpdir("alias");
    let mut rng = Rng::new(506);
    let (d, _) = data::gaussian_blobs(80, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 80, 4, &d, false).unwrap();

    let sub_prefix = dir.join("sub");
    let out = Command::new(bin())
        .args([
            "train", "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            input.to_str().unwrap(),
            sub_prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        !String::from_utf8_lossy(&out.stderr).contains("deprecated"),
        "subcommand form must not warn"
    );

    let flat_prefix = dir.join("flat");
    let out = Command::new(bin())
        .args([
            "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            input.to_str().unwrap(),
            flat_prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("deprecated"),
        "flat form must print the deprecation notice"
    );

    for ext in [".wts", ".bm", ".umx"] {
        let a = std::fs::read(format!("{}{ext}", sub_prefix.display())).unwrap();
        let b = std::fs::read(format!("{}{ext}", flat_prefix.display())).unwrap();
        assert_eq!(a, b, "{ext} diverged between train and flat alias");
    }
}

#[test]
fn keep_last_prunes_old_checkpoints() {
    // --keep-last N retains only the newest N cadence checkpoints.
    let dir = tmpdir("keep_last");
    let mut rng = Rng::new(507);
    let (d, _) = data::gaussian_blobs(60, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 60, 4, &d, false).unwrap();
    let prefix = dir.join("out");
    let out = Command::new(bin())
        .args([
            "train", "-e", "6", "-x", "5", "-y", "5", "-r", "2",
            "--checkpoint-every", "1", "--keep-last", "2",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    for k in [5, 6] {
        let p = format!("{}.epoch{k}.somc", prefix.display());
        assert!(std::path::Path::new(&p).exists(), "{p} should survive GC");
    }
    for k in [1, 2, 3, 4] {
        let p = format!("{}.epoch{k}.somc", prefix.display());
        assert!(!std::path::Path::new(&p).exists(), "{p} should be pruned");
    }
}

#[test]
fn bad_arguments_exit_nonzero_with_usage() {
    let out = Command::new(bin()).args(["--bogus", "a", "b"]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("Usage"), "{text}");

    let out = Command::new(bin())
        .args(["-g", "triangle", "in.txt", "out"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn missing_input_file_reports_cleanly() {
    let out = Command::new(bin())
        .args(["/nonexistent/input.txt", "/tmp/somoclu_nope"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("error"), "{text}");
}

#[test]
fn initial_codebook_flag_round_trips() {
    let dir = tmpdir("resume");
    let mut rng = Rng::new(503);
    let (d, _) = data::gaussian_blobs(80, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 80, 4, &d, false).unwrap();
    let p1 = dir.join("first");
    let status = Command::new(bin())
        .args([
            "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            input.to_str().unwrap(),
            p1.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success());

    // Resume from the produced .wts via -c.
    let p2 = dir.join("second");
    let wts = format!("{}.wts", p1.display());
    let out = Command::new(bin())
        .args([
            "-c", &wts, "-e", "2", "-x", "6", "-y", "6", "-r", "2",
            input.to_str().unwrap(),
            p2.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn accel_and_hybrid_kernels_via_cli() {
    // -k 1 / -k 3 end-to-end through the binary (needs artifacts).
    if !somoclu::runtime::Manifest::default_dir()
        .join("manifest.json")
        .exists()
    {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = tmpdir("accel");
    let mut rng = Rng::new(504);
    let (d, _) = data::gaussian_blobs(128, 8, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 128, 8, &d, false).unwrap();
    for k in ["1", "3"] {
        let prefix = dir.join(format!("out{k}"));
        let out = Command::new(bin())
            .env("SOMOCLU_ARTIFACTS",
                 somoclu::runtime::Manifest::default_dir())
            .args([
                "-k", k, "-e", "2", "-x", "8", "-y", "8", "-r", "4",
                input.to_str().unwrap(),
                prefix.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "-k {k}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(std::path::Path::new(&format!("{}.wts", prefix.display())).exists());
    }
}

#[test]
fn pca_initialization_via_cli() {
    let dir = tmpdir("pca");
    let mut rng = Rng::new(505);
    let (d, _) = data::gaussian_blobs(100, 6, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 100, 6, &d, false).unwrap();
    let prefix = dir.join("out");
    let out = Command::new(bin())
        .args([
            "--initialization", "pca", "-e", "3", "-x", "6", "-y", "6",
            "-r", "3",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn info_subcommand_prints_header_and_shards() {
    let dir = tmpdir("info");
    let mut rng = Rng::new(600);
    let (rows, dim) = (37usize, 5usize);
    let d: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let somb = dir.join("info.somb");
    somoclu::io::binary::write_binary_dense(&somb, rows, dim, &d).unwrap();

    let out = Command::new(bin())
        .args(["info", "--ranks", "4", somb.to_str().unwrap()])
        .output()
        .expect("binary runs");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("kind    dense"), "{stdout}");
    assert!(stdout.contains("rows    37"), "{stdout}");
    assert!(stdout.contains("dim     5"), "{stdout}");
    assert!(stdout.contains("rank 0"), "{stdout}");
    assert!(stdout.contains("rank 3"), "{stdout}");

    // Sparse container: nnz line + per-rank nnz windows.
    let m = Csr::random(20, 9, 0.3, &mut rng);
    let sbin = dir.join("info_sp.somb");
    somoclu::io::binary::write_binary_sparse(&sbin, &m).unwrap();
    let out = Command::new(bin())
        .args(["info", "--ranks", "2", sbin.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("sparse"), "{stdout}");
    assert!(stdout.contains("nnz"), "{stdout}");
}

#[test]
fn info_subcommand_rejects_corrupt_containers() {
    let dir = tmpdir("info_bad");

    // Not a container at all.
    let txt = dir.join("plain.txt");
    std::fs::write(&txt, "1 2\n3 4\n").unwrap();
    let out = Command::new(bin())
        .args(["info", txt.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("magic"));

    // Truncated container: header declares more payload than exists.
    let mut rng = Rng::new(601);
    let d: Vec<f32> = (0..60).map(|_| rng.normal_f32()).collect();
    let good = dir.join("good.somb");
    somoclu::io::binary::write_binary_dense(&good, 12, 5, &d).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    let trunc = dir.join("trunc.somb");
    std::fs::write(&trunc, &bytes[..bytes.len() - 7]).unwrap();
    let out = Command::new(bin())
        .args(["info", trunc.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("truncated"));

    // More ranks than rows: clean nonzero exit, not a panic.
    let out = Command::new(bin())
        .args(["info", "--ranks", "99", good.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("ranks"));
}

#[test]
fn io_backends_via_cli() {
    let dir = tmpdir("io_modes");
    let mut rng = Rng::new(602);
    let (rows, dim) = (80usize, 4usize);
    let d: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let somb = dir.join("data.somb");
    somoclu::io::binary::write_binary_dense(&somb, rows, dim, &d).unwrap();

    let run = |io: &str, extra: &[&str]| {
        let prefix = dir.join(format!("out_{io}{}", extra.len()));
        let mut args = vec![
            "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            "--chunk-rows", "16", "--ranks", "2", "--io", io,
        ];
        args.extend_from_slice(extra);
        let somb_s = somb.to_str().unwrap().to_string();
        let prefix_s = prefix.to_str().unwrap().to_string();
        args.push(&somb_s);
        args.push(&prefix_s);
        let out = Command::new(bin()).args(&args).output().expect("binary runs");
        (out, prefix)
    };

    let (out, prefix) = run("pread", &[]);
    assert!(out.status.success(), "pread: {}", String::from_utf8_lossy(&out.stderr));
    let pread_bm = std::fs::read(format!("{}.bm", prefix.display())).unwrap();

    let (out, prefix) = run("buffered", &["--prefetch"]);
    assert!(out.status.success(), "buffered: {}", String::from_utf8_lossy(&out.stderr));
    let buf_bm = std::fs::read(format!("{}.bm", prefix.display())).unwrap();
    assert_eq!(pread_bm, buf_bm, "pread BMUs diverged from buffered");

    // mmap: identical when the backend exists, clean error otherwise.
    let (out, prefix) = run("mmap", &[]);
    if somoclu::io::mmap::SUPPORTED {
        assert!(out.status.success(), "mmap: {}", String::from_utf8_lossy(&out.stderr));
        let mmap_bm = std::fs::read(format!("{}.bm", prefix.display())).unwrap();
        assert_eq!(pread_bm, mmap_bm, "mmap BMUs diverged from buffered");
    } else {
        assert!(!out.status.success());
        assert!(String::from_utf8_lossy(&out.stderr).contains("mmap"));
    }

    // mmap + prefetch: rejected up front with an actionable message.
    let (out, _) = run("mmap", &["--prefetch"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("prefetch"));

    // --io on a text input: refused with the convert hint.
    let txt = dir.join("data.txt");
    dense::write_dense(&txt, rows, dim, &d, false).unwrap();
    let out = Command::new(bin())
        .args([
            "--io", "pread", "--chunk-rows", "16",
            txt.to_str().unwrap(),
            dir.join("out_txt").to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("convert"));
}

#[test]
fn checkpoint_and_resume_complete_a_half_trained_run() {
    // The ISSUE 4 acceptance bar end-to-end: a full CLI run with
    // --checkpoint-every leaves mid-schedule SOMC artifacts; `somoclu
    // --resume` finishes from the half-trained one and the outputs are
    // BYTE-identical to the uninterrupted run's.
    let dir = tmpdir("ckpt");
    let mut rng = Rng::new(510);
    let (d, _) = data::gaussian_blobs(90, 5, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 90, 5, &d, false).unwrap();

    let full_prefix = dir.join("full");
    let out = Command::new(bin())
        .args([
            "-e", "6", "-x", "6", "-y", "6", "-r", "3", "--threads", "2",
            "--checkpoint-every", "2",
            input.to_str().unwrap(),
            full_prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // Numbered checkpoints at every cadence point.
    for k in [2, 4, 6] {
        let p = format!("{}.epoch{k}.somc", full_prefix.display());
        assert!(std::path::Path::new(&p).exists(), "{p}");
    }

    // Resume the half-trained (epoch-4) artifact — exactly what a crash
    // at epoch 5 would have left behind.
    let resumed_prefix = dir.join("resumed");
    let ckpt = format!("{}.epoch4.somc", full_prefix.display());
    let out = Command::new(bin())
        .args([
            "--resume", &ckpt, "--threads", "2",
            input.to_str().unwrap(),
            resumed_prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("resumed"), "{stderr}");
    assert!(stderr.contains("epoch 4/6"), "{stderr}");

    // Resume-equivalence holds exactly: same .wts/.bm bytes.
    for ext in [".wts", ".bm", ".umx"] {
        let a = std::fs::read(format!("{}{ext}", full_prefix.display())).unwrap();
        let b = std::fs::read(format!("{}{ext}", resumed_prefix.display())).unwrap();
        assert_eq!(a, b, "{ext} diverged between full and resumed runs");
    }

    // A streamed resume (--chunk-rows) finishes too and matches the
    // streamed uninterrupted run.
    let s_full = dir.join("sfull");
    let out = Command::new(bin())
        .args([
            "-e", "4", "-x", "6", "-y", "6", "-r", "3", "--threads", "2",
            "--chunk-rows", "8", "--checkpoint-every", "2",
            input.to_str().unwrap(),
            s_full.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(out.status.success());
    let s_resumed = dir.join("sresumed");
    let ckpt = format!("{}.epoch2.somc", s_full.display());
    let out = Command::new(bin())
        .args([
            "--resume", &ckpt, "--threads", "2", "--chunk-rows", "8",
            input.to_str().unwrap(),
            s_resumed.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    for ext in [".wts", ".bm"] {
        let a = std::fs::read(format!("{}{ext}", s_full.display())).unwrap();
        let b = std::fs::read(format!("{}{ext}", s_resumed.display())).unwrap();
        assert_eq!(a, b, "streamed {ext} diverged");
    }

    // A corrupt checkpoint is refused with a clear error.
    let bad = dir.join("bad.somc");
    let mut bytes = std::fs::read(format!("{}.epoch4.somc", full_prefix.display())).unwrap();
    let n = bytes.len();
    bytes[n - 2] ^= 0x20;
    std::fs::write(&bad, &bytes).unwrap();
    let out = Command::new(bin())
        .args([
            "--resume", bad.to_str().unwrap(),
            input.to_str().unwrap(),
            dir.join("nope").to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("checksum"));
}

#[test]
fn resume_with_conflicting_codebook_flag_rejected() {
    let out = Command::new(bin())
        .args(["--resume", "x.somc", "-c", "cb.wts", "in.txt", "out"])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("resume"));
}
