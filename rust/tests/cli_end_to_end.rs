//! End-to-end through the real binary: write input files, invoke the
//! `somoclu` CLI exactly as the paper's examples do, check outputs.

use std::path::PathBuf;
use std::process::Command;

use somoclu::data;
use somoclu::io::{dense, read_dense, sparse as sparse_io};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn bin() -> PathBuf {
    // target/<profile>/somoclu next to the test executable.
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("somoclu");
    p
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("somoclu_cli_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn paper_basic_invocation() {
    // "$ Somoclu data/rgbs.txt data/rgbs" — scaled-down map for speed.
    let dir = tmpdir("basic");
    let mut rng = Rng::new(500);
    let (d, _) = data::rgb_toy(120, &mut rng);
    let input = dir.join("rgbs.txt");
    dense::write_dense(&input, 120, 3, &d, false).unwrap();
    let prefix = dir.join("rgbs");

    let out = Command::new(bin())
        .args([
            "-e", "4", "-x", "8", "-y", "8", "-r", "4",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    for ext in [".wts", ".bm", ".umx"] {
        let p = format!("{}{ext}", prefix.display());
        assert!(std::path::Path::new(&p).exists(), "{p}");
    }
    let wts = read_dense(format!("{}.wts", prefix.display())).unwrap();
    assert_eq!((wts.rows, wts.cols), (64, 3));
}

#[test]
fn paper_cluster_invocation() {
    // "mpirun -np 4 Somoclu -k 0 --rows 20 --columns 20 ..." with
    // --ranks standing in for mpirun.
    let dir = tmpdir("cluster");
    let mut rng = Rng::new(501);
    let (d, _) = data::gaussian_blobs(160, 6, 4, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 160, 6, &d, false).unwrap();
    let prefix = dir.join("out");

    let out = Command::new(bin())
        .args([
            "--ranks", "4", "-k", "0", "--rows", "10", "--columns", "10",
            "-e", "4", "-r", "5", "-v",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("cluster: 4 ranks"), "{stderr}");
    assert!(stderr.contains("epoch"), "{stderr}");
}

#[test]
fn sparse_kernel_invocation() {
    let dir = tmpdir("sparse");
    let mut rng = Rng::new(502);
    let m = Csr::random(100, 50, 0.1, &mut rng);
    let input = dir.join("data.svm");
    sparse_io::write_sparse(&input, &m).unwrap();
    let prefix = dir.join("out");

    let out = Command::new(bin())
        .args([
            "-k", "2", "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("sparse input"), "{stderr}");
}

#[test]
fn help_lists_paper_flags() {
    let out = Command::new(bin()).arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for flag in [
        "-c", "-e", "-g", "-k", "-m", "-n", "-p", "-t", "-r", "-R", "-T",
        "-l", "-L", "-s", "-x", "-y", "--ranks", "INPUT_FILE",
        "OUTPUT_PREFIX",
    ] {
        assert!(text.contains(flag), "missing {flag} in:\n{text}");
    }
}

#[test]
fn bad_arguments_exit_nonzero_with_usage() {
    let out = Command::new(bin()).args(["--bogus", "a", "b"]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("Usage"), "{text}");

    let out = Command::new(bin())
        .args(["-g", "triangle", "in.txt", "out"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn missing_input_file_reports_cleanly() {
    let out = Command::new(bin())
        .args(["/nonexistent/input.txt", "/tmp/somoclu_nope"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("error"), "{text}");
}

#[test]
fn initial_codebook_flag_round_trips() {
    let dir = tmpdir("resume");
    let mut rng = Rng::new(503);
    let (d, _) = data::gaussian_blobs(80, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 80, 4, &d, false).unwrap();
    let p1 = dir.join("first");
    let status = Command::new(bin())
        .args([
            "-e", "3", "-x", "6", "-y", "6", "-r", "3",
            input.to_str().unwrap(),
            p1.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success());

    // Resume from the produced .wts via -c.
    let p2 = dir.join("second");
    let wts = format!("{}.wts", p1.display());
    let out = Command::new(bin())
        .args([
            "-c", &wts, "-e", "2", "-x", "6", "-y", "6", "-r", "2",
            input.to_str().unwrap(),
            p2.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn accel_and_hybrid_kernels_via_cli() {
    // -k 1 / -k 3 end-to-end through the binary (needs artifacts).
    if !somoclu::runtime::Manifest::default_dir()
        .join("manifest.json")
        .exists()
    {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = tmpdir("accel");
    let mut rng = Rng::new(504);
    let (d, _) = data::gaussian_blobs(128, 8, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 128, 8, &d, false).unwrap();
    for k in ["1", "3"] {
        let prefix = dir.join(format!("out{k}"));
        let out = Command::new(bin())
            .env("SOMOCLU_ARTIFACTS",
                 somoclu::runtime::Manifest::default_dir())
            .args([
                "-k", k, "-e", "2", "-x", "8", "-y", "8", "-r", "4",
                input.to_str().unwrap(),
                prefix.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "-k {k}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(std::path::Path::new(&format!("{}.wts", prefix.display())).exists());
    }
}

#[test]
fn pca_initialization_via_cli() {
    let dir = tmpdir("pca");
    let mut rng = Rng::new(505);
    let (d, _) = data::gaussian_blobs(100, 6, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 100, 6, &d, false).unwrap();
    let prefix = dir.join("out");
    let out = Command::new(bin())
        .args([
            "--initialization", "pca", "-e", "3", "-x", "6", "-y", "6",
            "-r", "3",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}
