//! ISSUE 9 acceptance: ensemble training + map-quality toolkit.
//!
//!  * `somoclu ensemble -k 8` produces a bit-deterministic consensus
//!    labeling for a fixed seed across `--threads` 1/4/16, with a
//!    per-sample agreement score — checked byte-for-byte on the
//!    `.consensus.lbl` and `.ensemble.json` artifacts, and again at the
//!    library level through [`EnsembleBuilder`].
//!  * `somoclu quality` emits valid versioned JSON whose QE/TE match
//!    the `som::quality` library functions **exactly** (the JSON writer
//!    prints shortest-round-trip floats, so parsing back recovers the
//!    identical f64 bits).
//!  * Trustworthiness / neighborhood preservation are pinned against a
//!    naive O(N³) counting-rank oracle that never sorts — a genuinely
//!    different route to the same integer penalties.
//!  * The quality-invariance harness accepts thread-count-only changes
//!    at `tol = 0.0` (the metrics are designed bit-stable).

use std::path::PathBuf;
use std::process::Command;

use somoclu::api::DataInput;
use somoclu::coordinator::config::TrainConfig;
use somoclu::data;
use somoclu::ensemble::EnsembleBuilder;
use somoclu::io::dense;
use somoclu::session::Som;
use somoclu::som::grid::{Grid, GridType, MapType};
use somoclu::som::quality::{
    self, assert_quality_invariant, rank_metrics, QualityReport,
};
use somoclu::util::json::Json;
use somoclu::util::rng::Rng;

fn bin() -> PathBuf {
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("somoclu");
    p
}

fn tmpdir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("somoclu_ens_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_config(seed: u64) -> TrainConfig {
    TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 3,
        radius0: Some(3.0),
        seed,
        ..TrainConfig::default()
    }
}

/// The acceptance bar at the library level: 8 members, consensus bits
/// identical across total thread budgets 1/4/16.
#[test]
fn ensemble_consensus_bit_deterministic_across_thread_budgets() {
    let mut rng = Rng::new(0xE25E);
    let (d, _) = data::gaussian_blobs(60, 4, 3, 0.2, &mut rng);
    let run = |threads: usize| {
        let mut cfg = small_config(41);
        cfg.threads = threads;
        EnsembleBuilder::new()
            .config(cfg)
            .members(8)
            .clusters(4)
            .run(&d, 4)
            .expect("ensemble trains")
    };
    let base = run(1);
    assert_eq!(base.members.len(), 8);
    assert_eq!(base.consensus.labels.len(), 60);
    for threads in [4usize, 16] {
        let r = run(threads);
        assert_eq!(r.consensus.labels, base.consensus.labels, "threads={threads}");
        for (i, (a, b)) in r
            .consensus
            .agreement
            .iter()
            .zip(&base.consensus.agreement)
            .enumerate()
        {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "agreement[{i}] diverged at threads={threads}"
            );
        }
        assert_eq!(
            r.consensus.mean_agreement.to_bits(),
            base.consensus.mean_agreement.to_bits(),
            "threads={threads}"
        );
        for (m, (x, y)) in r.members.iter().zip(&base.members).enumerate() {
            assert_eq!(x.bmus, y.bmus, "member {m} BMUs diverged at threads={threads}");
            assert_eq!(x.labels, y.labels, "member {m} labels diverged");
        }
    }
}

/// Same bar through the real binary: `somoclu ensemble -k 8` with
/// `--threads` 1/4/16 writes byte-identical `.consensus.lbl` and
/// `.ensemble.json`, plus one `.m<i>.bm` per member.
#[test]
fn ensemble_cli_artifacts_byte_identical_across_threads() {
    let dir = tmpdir("cli_det");
    let mut rng = Rng::new(0xC11E);
    let (d, _) = data::gaussian_blobs(60, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 60, 4, &d, false).unwrap();

    let mut artifacts: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
    for threads in ["1", "4", "16"] {
        let prefix = dir.join(format!("out_t{threads}"));
        let out = Command::new(bin())
            .args([
                "ensemble", "-k", "8", "-c", "4", "-e", "3", "-x", "6", "-y", "6",
                "-r", "3", "--seed", "99", "--threads", threads, "-v",
                input.to_str().unwrap(),
                prefix.to_str().unwrap(),
            ])
            .output()
            .expect("binary runs");
        assert!(
            out.status.success(),
            "threads={threads}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains("mean"), "{stderr}");
        for i in 0..8 {
            let p = format!("{}.m{i}.bm", prefix.display());
            assert!(std::path::Path::new(&p).exists(), "{p}");
        }
        let lbl = std::fs::read(format!("{}.consensus.lbl", prefix.display())).unwrap();
        let json = std::fs::read(format!("{}.ensemble.json", prefix.display())).unwrap();
        artifacts.push((lbl, json));
    }
    for (i, (lbl, json)) in artifacts.iter().enumerate().skip(1) {
        assert_eq!(lbl, &artifacts[0].0, "consensus.lbl diverged (run {i})");
        assert_eq!(json, &artifacts[0].1, "ensemble.json diverged (run {i})");
    }

    // The labeling itself is well-formed: header, one line per sample,
    // labels inside [0, clusters), agreement in (0, 1].
    let text = String::from_utf8(artifacts[0].0.clone()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "% 60");
    assert_eq!(lines.len(), 61);
    for (i, line) in lines[1..].iter().enumerate() {
        let f: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(f.len(), 3, "{line}");
        assert_eq!(f[0].parse::<usize>().unwrap(), i);
        assert!(f[1].parse::<u32>().unwrap() < 4, "{line}");
        let a: f32 = f[2].parse().unwrap();
        assert!(a > 0.0 && a <= 1.0, "{line}");
    }

    // And the JSON report is versioned and self-consistent.
    let json = Json::parse(std::str::from_utf8(&artifacts[0].1).unwrap())
        .expect("valid JSON");
    assert_eq!(json.get("version").and_then(Json::as_usize), Some(1));
    assert_eq!(json.get("members").and_then(Json::as_usize), Some(8));
    assert_eq!(json.get("clusters").and_then(Json::as_usize), Some(4));
    assert_eq!(json.get("samples").and_then(Json::as_usize), Some(60));
    let ma = json.get("mean_agreement").and_then(Json::as_f64).unwrap();
    assert!((0.0..=1.0).contains(&ma), "{ma}");
    let stats = json.get("member_stats").and_then(Json::as_arr).unwrap();
    assert_eq!(stats.len(), 8);
    let seeds: std::collections::BTreeSet<u64> = stats
        .iter()
        .map(|s| {
            s.get("seed")
                .and_then(Json::as_str)
                .unwrap()
                .parse::<u64>()
                .expect("u64 seed survives the string round-trip")
        })
        .collect();
    assert_eq!(seeds.len(), 8, "member seeds must be distinct");
}

/// `somoclu quality` end-to-end: train → checkpoint → evaluate. The
/// emitted JSON parses, is schema-version 1, and its QE/TE/rank values
/// recover the **identical f64 bits** the library computes on the same
/// checkpoint + data.
#[test]
fn quality_cli_json_matches_library_bit_for_bit() {
    let dir = tmpdir("quality");
    let mut rng = Rng::new(0x0A11);
    let (d, _) = data::gaussian_blobs(50, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 50, 4, &d, false).unwrap();
    let prefix = dir.join("map");
    let out = Command::new(bin())
        .args([
            "train", "-e", "4", "-x", "6", "-y", "6", "-r", "3",
            "--checkpoint-every", "4",
            input.to_str().unwrap(),
            prefix.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ckpt = format!("{}.epoch4.somc", prefix.display());
    assert!(std::path::Path::new(&ckpt).exists(), "{ckpt}");

    let out = Command::new(bin())
        .args(["quality", "-k", "5", &ckpt, input.to_str().unwrap()])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = Json::parse(&String::from_utf8_lossy(&out.stdout))
        .expect("quality emits valid JSON");

    // Library route over the same artifacts.
    let mut session = Som::resume(&ckpt).expect("checkpoint resumes");
    let codebook = session.codebook().expect("codebook").clone();
    let bmus = session
        .project(DataInput::BorrowedF32 { data: &d, dim: 4 })
        .expect("projection");
    let umatrix = session.umatrix();
    let report = QualityReport::compute(
        &d, 4, session.grid(), &codebook, &bmus, umatrix.as_deref(), 5, 2,
    );

    assert_eq!(json.get("version").and_then(Json::as_usize), Some(1));
    assert_eq!(json.get("rows").and_then(Json::as_usize), Some(50));
    assert_eq!(json.get("dim").and_then(Json::as_usize), Some(4));
    assert_eq!(json.get("knn").and_then(Json::as_usize), Some(report.rank.k));
    let map = json.get("map").expect("map object");
    assert_eq!(map.get("rows").and_then(Json::as_usize), Some(6));
    assert_eq!(map.get("cols").and_then(Json::as_usize), Some(6));
    assert_eq!(map.get("grid").and_then(Json::as_str), Some("square"));
    assert_eq!(map.get("topology").and_then(Json::as_str), Some("planar"));

    // The acceptance criterion: CLI QE/TE == library QE/TE, exactly.
    let get = |k: &str| json.get(k).and_then(Json::as_f64).unwrap();
    assert_eq!(get("qe").to_bits(), (report.qe as f64).to_bits());
    assert_eq!(get("te").to_bits(), (report.te as f64).to_bits());
    assert_eq!(
        get("trustworthiness").to_bits(),
        report.rank.trustworthiness.to_bits()
    );
    assert_eq!(
        get("neighborhood_preservation").to_bits(),
        report.rank.neighborhood_preservation.to_bits()
    );
    let planes = json.get("component_planes").and_then(Json::as_arr).unwrap();
    assert_eq!(planes.len(), 4);
    let um = json.get("umatrix").expect("umatrix key present");
    let um_mean = um.get("mean").and_then(Json::as_f64).unwrap();
    assert_eq!(um_mean.to_bits(), report.umatrix.unwrap().mean.to_bits());
    assert!(json.get("plane_values").is_none(), "no --planes, no dump");

    // --planes + -o FILE: the heavy export lands on disk with one row of
    // node values per input dimension.
    let report_path = dir.join("report.json");
    let out = Command::new(bin())
        .args([
            "quality", "-k", "5", "--planes", "-o", report_path.to_str().unwrap(),
            &ckpt, input.to_str().unwrap(),
        ])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(out.stdout.is_empty(), "-o must silence stdout");
    let json = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
    let pv = json.get("plane_values").and_then(Json::as_arr).unwrap();
    assert_eq!(pv.len(), 4);
    for p in pv {
        assert_eq!(p.as_arr().unwrap().len(), 36);
    }
}

/// Counting-rank oracle for Venna & Kaski trustworthiness/preservation:
/// rank(i,j) = 1 + #{l : (d(i,l), l) < (d(i,j), j)} under the same
/// (total_cmp, index) tie-break the library sorts by — no sorting, no
/// shared code with `rank_metrics`.
fn oracle_rank_metrics(
    data: &[f32],
    dim: usize,
    grid: &Grid,
    bmus: &[u32],
    k: usize,
) -> (f64, f64) {
    let n = bmus.len();
    assert!(n > 3);
    let k_eff = k.min((2 * n - 2) / 3).max(1) as u64;
    let d_in = |i: usize, j: usize| {
        quality::sq_dist(&data[i * dim..(i + 1) * dim], &data[j * dim..(j + 1) * dim])
    };
    let d_out =
        |i: usize, j: usize| grid.distance(bmus[i] as usize, bmus[j] as usize);
    let lt = |da: f32, a: usize, db: f32, b: usize| {
        match da.total_cmp(&db) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => a < b,
        }
    };
    let rank = |d: &dyn Fn(usize, usize) -> f32, i: usize, j: usize| -> u64 {
        1 + (0..n)
            .filter(|&l| l != i && l != j && lt(d(i, l), l, d(i, j), j))
            .count() as u64
    };
    let (mut trust, mut np) = (0u64, 0u64);
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            let r_in = rank(&d_in, i, j);
            let r_out = rank(&d_out, i, j);
            // j inside i's map-space k-NN but outside its input k-NN.
            if r_out <= k_eff && r_in > k_eff {
                trust += r_in - k_eff;
            }
            // j inside i's input k-NN but outside its map-space k-NN.
            if r_in <= k_eff && r_out > k_eff {
                np += r_out - k_eff;
            }
        }
    }
    let norm =
        2.0 / (n as f64 * k_eff as f64 * (2 * n as u64 - 3 * k_eff - 1) as f64);
    (1.0 - norm * trust as f64, 1.0 - norm * np as f64)
}

/// `rank_metrics` equals the counting oracle exactly — every grid type,
/// several k, several thread counts, including heavy BMU pileups (many
/// samples on one node ⇒ massed distance ties resolved by index).
#[test]
fn rank_metrics_match_naive_counting_oracle() {
    let mut rng = Rng::new(0x7AB5);
    let grids = [
        Grid::new(7, 5, GridType::Square, MapType::Planar),
        Grid::new(5, 6, GridType::Hexagonal, MapType::Toroid),
    ];
    for grid in &grids {
        let n = 40;
        let dim = 3;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32()).collect();
        // Pile BMUs onto few nodes so map-space ties are everywhere.
        let bmus: Vec<u32> =
            (0..n).map(|_| rng.below(6) as u32 * 3).collect();
        for k in [1usize, 3, 10, 100] {
            let (ot, onp) = oracle_rank_metrics(&data, dim, grid, &bmus, k);
            for threads in [1usize, 3] {
                let m = rank_metrics(&data, dim, grid, &bmus, k, threads);
                let ctx = format!(
                    "{:?}/{:?} k={k} threads={threads}",
                    grid.grid_type, grid.map_type
                );
                assert_eq!(m.trustworthiness.to_bits(), ot.to_bits(), "{ctx}");
                assert_eq!(
                    m.neighborhood_preservation.to_bits(),
                    onp.to_bits(),
                    "{ctx}"
                );
            }
        }
    }
}

/// The invariance harness holds at `tol = 0.0` for a pure thread-count
/// change — the guarantee perf PRs will lean on.
#[test]
fn quality_reports_thread_invariant_under_harness() {
    let mut rng = Rng::new(0x1A47);
    let (d, _) = data::gaussian_blobs(45, 4, 3, 0.25, &mut rng);
    let cfg = small_config(7);
    let mut session = Som::builder().config(cfg).build().expect("builds");
    session
        .fit(DataInput::BorrowedF32 { data: &d, dim: 4 })
        .expect("trains");
    let codebook = session.codebook().expect("codebook").clone();
    let bmus = session
        .project(DataInput::BorrowedF32 { data: &d, dim: 4 })
        .expect("projection");
    let um = session.umatrix();
    let mk = |threads: usize| {
        QualityReport::compute(
            &d, 4, session.grid(), &codebook, &bmus, um.as_deref(), 6, threads,
        )
    };
    let a = mk(1);
    for threads in [2usize, 4, 16] {
        assert_quality_invariant(&a, &mk(threads), 0.0);
    }
}

/// Ensemble member checkpoints resume to bit-identical consensus through
/// the CLI: interrupt-free and resumed runs write identical artifacts.
#[test]
fn ensemble_cli_checkpoint_resume_is_bit_identical() {
    let dir = tmpdir("cli_resume");
    let mut rng = Rng::new(0xFEED);
    let (d, _) = data::gaussian_blobs(40, 4, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 40, 4, &d, false).unwrap();

    let run = |prefix: &PathBuf| {
        let out = Command::new(bin())
            .args([
                "ensemble", "-k", "3", "-c", "3", "-e", "3", "-x", "5", "-y", "5",
                "-r", "2", "--seed", "11", "--checkpoint-every", "1",
                input.to_str().unwrap(),
                prefix.to_str().unwrap(),
            ])
            .output()
            .expect("binary runs");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    };
    let full = dir.join("full");
    run(&full);
    // Re-running over the existing member checkpoints resumes (the
    // final-epoch .somc files are found and nothing retrains) and must
    // reproduce the same consensus bytes.
    let before =
        std::fs::read(format!("{}.consensus.lbl", full.display())).unwrap();
    run(&full);
    let after =
        std::fs::read(format!("{}.consensus.lbl", full.display())).unwrap();
    assert_eq!(before, after, "resumed consensus diverged");

    // And a fresh prefix with the same seed gives those same bytes too.
    let fresh = dir.join("fresh");
    run(&fresh);
    let fresh_lbl =
        std::fs::read(format!("{}.consensus.lbl", fresh.display())).unwrap();
    assert_eq!(before, fresh_lbl, "checkpointed vs fresh consensus diverged");
}
