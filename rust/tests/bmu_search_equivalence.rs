//! Exact-BMU / exact-distance equivalence for the blocked BMU-search
//! microkernel (ISSUE 6): the panel-tiled, runtime-dispatched search
//! must be **bit-identical** to the pre-refactor 8-row block scan for
//! the kernel kind the old per-call detection would have picked on this
//! machine.
//!
//! The oracle below is a *verbatim copy* of the deleted code
//! (`search_bmus` + `dot8`/`dot8_avx2`/`dot_unrolled` as of PR 5), run
//! single-threaded — per-row results never depended on the thread split,
//! and thread invariance of the new path is asserted separately.
//!
//! Covered, per the issue: rows % 8 ≠ 0 tails, dims that are not a
//! multiple of the SIMD width, a panel-size sweep (panels straddling
//! duplicated rows), thread-count invariance, the sparse kernel's argmin,
//! `best_two` vs the old naive pass, the duplicated-row/zero-distance
//! tie adversary, and the 1e6-row f64-accumulation QE property.

use somoclu::kernels::dense_cpu::search_bmus_blocked;
use somoclu::kernels::simd::{self, SimdKind};
use somoclu::kernels::{DataShard, TrainingKernel};
use somoclu::som::quality::{best_two, quantization_error, sq_dist, topographic_error};
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::prop::{check_with, Config};
use somoclu::util::rng::Rng;

// ---------------------------------------------------------------------
// Verbatim pre-refactor oracle (threads = 1).
// ---------------------------------------------------------------------

fn oracle_search_bmus(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    w2: &[f32],
) -> (Vec<u32>, Vec<f32>) {
    let rows = data.len() / dim;
    let mut bmus = Vec::with_capacity(rows);
    let mut dists = Vec::with_capacity(rows);
    const B: usize = 8;
    let mut it = (0..rows).peekable();
    while let Some(r0) = it.next() {
        let mut block = [r0; B];
        let mut blen = 1;
        while blen < B {
            match it.next() {
                Some(r) => {
                    block[blen] = r;
                    blen += 1;
                }
                None => break,
            }
        }
        let x: [&[f32]; B] = std::array::from_fn(|k| &data[block[k] * dim..(block[k] + 1) * dim]);
        let mut x2 = [0.0f32; B];
        for k in 0..blen {
            x2[k] = x[k].iter().map(|v| v * v).sum();
        }
        let mut best = [0u32; B];
        let mut best_score = [f32::INFINITY; B];
        for n in 0..codebook.nodes {
            let w = codebook.row(n);
            let half_w2 = 0.5 * w2[n];
            let dots = oracle_dot8(&x, w);
            for k in 0..blen {
                let score = half_w2 - dots[k];
                if score < best_score[k] {
                    best_score[k] = score;
                    best[k] = n as u32;
                }
            }
        }
        for k in 0..blen {
            let d2 = (x2[k] + 2.0 * best_score[k]).max(0.0);
            bmus.push(best[k]);
            dists.push(d2);
        }
    }
    (bmus, dists)
}

#[inline]
fn oracle_dot8(x: &[&[f32]; 8], w: &[f32]) -> [f32; 8] {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return unsafe { oracle_dot8_avx2(x, w) };
        }
    }
    let mut out = [0.0f32; 8];
    for k in 0..8 {
        out[k] = oracle_dot_unrolled(x[k], w);
    }
    out
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn oracle_dot8_avx2(x: &[&[f32]; 8], w: &[f32]) -> [f32; 8] {
    use std::arch::x86_64::*;
    let d = w.len();
    let chunks = d / 8;
    unsafe {
        let mut acc = [_mm256_setzero_ps(); 8];
        let wp = w.as_ptr();
        let xp: [*const f32; 8] = std::array::from_fn(|k| x[k].as_ptr());
        for c in 0..chunks {
            let o = (c * 8) as isize;
            let wv = _mm256_loadu_ps(wp.offset(o));
            for k in 0..8 {
                acc[k] = _mm256_fmadd_ps(_mm256_loadu_ps(xp[k].offset(o)), wv, acc[k]);
            }
        }
        #[inline]
        unsafe fn hsum(v: std::arch::x86_64::__m256) -> f32 {
            unsafe {
                let lo = _mm256_castps256_ps128(v);
                let hi = _mm256_extractf128_ps(v, 1);
                let s = _mm_add_ps(lo, hi);
                let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
                let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
                _mm_cvtss_f32(s)
            }
        }
        let mut out: [f32; 8] = std::array::from_fn(|k| hsum(acc[k]));
        for i in chunks * 8..d {
            for k in 0..8 {
                out[k] = x[k][i].mul_add(w[i], out[k]);
            }
        }
        out
    }
}

#[inline]
fn oracle_dot_unrolled(x: &[f32], w: &[f32]) -> f32 {
    let chunks = x.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let xb = &x[c * 8..c * 8 + 8];
        let wb = &w[c * 8..c * 8 + 8];
        for k in 0..8 {
            acc[k] = xb[k].mul_add(wb[k], acc[k]);
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..x.len() {
        tail = x[i].mul_add(w[i], tail);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// The kind the *old* per-call detection selected on this machine —
/// derived from the same macro the oracle uses, deliberately not from
/// `simd::dispatch()` (which honors `SOMOCLU_FORCE_SCALAR`; the oracle
/// never did).
fn historical_kind() -> SimdKind {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        return SimdKind::Avx2Fma;
    }
    SimdKind::Scalar
}

fn rand_data(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn assert_bits_equal(
    (gb, gd): &(Vec<u32>, Vec<f32>),
    (wb, wd): &(Vec<u32>, Vec<f32>),
    what: &str,
) {
    assert_eq!(gb, wb, "{what}: BMU mismatch");
    for (i, (a, b)) in gd.iter().zip(wd).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: dist[{i}] {a} vs {b}");
    }
}

// ---------------------------------------------------------------------
// Tentpole: bit-identity to the pre-refactor search.
// ---------------------------------------------------------------------

/// Property: for random shapes — including rows % 8 ≠ 0 tails and dims
/// that are not multiples of the SIMD width — the blocked search equals
/// the verbatim pre-refactor search bit for bit (BMUs and reconstructed
/// distances), at the default panel size and with multiple threads.
#[test]
fn blocked_search_matches_pre_refactor_bits() {
    let kind = historical_kind();
    check_with(
        Config {
            cases: 40,
            ..Config::default()
        },
        "blocked search == pre-refactor search",
        |g| {
            let rows = g.usize_in(1, 70);
            let dim = *g.choice(&[1usize, 3, 5, 7, 8, 9, 13, 16, 17, 31, 40]);
            let nodes = g.usize_in(1, 60);
            let mut rng = Rng::new(g.rng.next_u64());
            let cb = Codebook {
                nodes,
                dim,
                weights: rand_data(&mut rng, nodes * dim),
            };
            let data = rand_data(&mut rng, rows * dim);
            let w2 = cb.sq_norms();
            let want = oracle_search_bmus(&data, dim, &cb, &w2);
            for threads in [1usize, 3] {
                let got = search_bmus_blocked(
                    &data,
                    dim,
                    &cb,
                    &w2,
                    threads,
                    kind,
                    simd::default_panel_nodes(dim),
                );
                if got.0 != want.0 {
                    return Err(format!(
                        "BMUs diverge (rows={rows} dim={dim} nodes={nodes} threads={threads})"
                    ));
                }
                for (a, b) in got.1.iter().zip(&want.1) {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "dist bits diverge: {a} vs {b} \
                             (rows={rows} dim={dim} nodes={nodes} threads={threads})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Panel-size sweep: every panel size — down to a single node per panel,
/// past sizes that straddle duplicated rows, up to far-larger-than-N —
/// produces bit-identical output to the flat scan (panel = all nodes,
/// which is algorithmically the pre-refactor loop nest).
#[test]
fn panel_size_invariant_bits() {
    let kind = historical_kind();
    let mut rng = Rng::new(61);
    let (nodes, dim, rows) = (37usize, 19usize, 29usize);
    let mut weights = rand_data(&mut rng, nodes * dim);
    // Duplicate runs of codebook rows so several panel sizes cut straight
    // through a tie group.
    for n in [4usize, 5, 17, 18, 19, 36] {
        let src: Vec<f32> = weights[3 * dim..4 * dim].to_vec();
        weights[n * dim..(n + 1) * dim].copy_from_slice(&src);
    }
    let cb = Codebook {
        nodes,
        dim,
        weights,
    };
    let data = rand_data(&mut rng, rows * dim);
    let w2 = cb.sq_norms();
    let flat = search_bmus_blocked(&data, dim, &cb, &w2, 2, kind, nodes);
    for panel in [1usize, 2, 3, 5, 8, 16, 17, 18, 19, 36, 37, 64, 1000] {
        let got = search_bmus_blocked(&data, dim, &cb, &w2, 2, kind, panel);
        assert_bits_equal(&got, &flat, &format!("panel={panel}"));
    }
}

#[test]
fn thread_count_invariant_bits() {
    let kind = historical_kind();
    let mut rng = Rng::new(62);
    let (nodes, dim, rows) = (25usize, 12usize, 83usize);
    let cb = Codebook {
        nodes,
        dim,
        weights: rand_data(&mut rng, nodes * dim),
    };
    let data = rand_data(&mut rng, rows * dim);
    let w2 = cb.sq_norms();
    let one = search_bmus_blocked(&data, dim, &cb, &w2, 1, kind, 8);
    for threads in [2usize, 4, 8, 16] {
        let got = search_bmus_blocked(&data, dim, &cb, &w2, threads, kind, 8);
        assert_bits_equal(&got, &one, &format!("threads={threads}"));
    }
}

/// The dense kernel's public surface (project / epoch_accumulate) still
/// returns the oracle's BMUs after the rewiring.
#[test]
fn dense_kernel_end_to_end_matches_oracle() {
    // Guard: only meaningful when dispatch picked what the oracle picks
    // (i.e. SOMOCLU_FORCE_SCALAR is not set on an AVX2 machine).
    if simd::dispatch() != historical_kind() {
        eprintln!("skipping: SOMOCLU_FORCE_SCALAR overrides the historical kind");
        return;
    }
    let mut rng = Rng::new(63);
    let grid = Grid::new(5, 5, GridType::Square, MapType::Planar);
    let cb = Codebook::random_init(25, 11, &mut rng);
    let data = rand_data(&mut rng, 42 * 11);
    let w2 = cb.sq_norms();
    let (want_bmus, want_dists) = oracle_search_bmus(&data, 11, &cb, &w2);
    let mut k = somoclu::kernels::dense_cpu::DenseCpuKernel::new(3);
    let got = k
        .epoch_accumulate(
            DataShard::Dense {
                data: &data,
                dim: 11,
            },
            &cb,
            &grid,
            Neighborhood::gaussian(false),
            2.0,
            0.7,
        )
        .unwrap();
    assert_eq!(got.bmus, want_bmus);
    let want_qe: f64 = want_dists.iter().map(|d| (*d as f64).sqrt()).sum();
    assert_eq!(got.qe_sum.to_bits(), want_qe.to_bits());
}

// ---------------------------------------------------------------------
// Tie rule: duplicated codebook rows + zero-distance rows, across panel
// boundaries, scalar reference vs dispatched microkernel.
// ---------------------------------------------------------------------

/// Adversarial tie property: codebook rows live on a coarse integer
/// lattice with whole groups duplicated, and every data row sits
/// *exactly on* one codebook row (zero distance, which also exercises
/// the `max(0)` clamp). Winner margins are integer-sized, so scalar and
/// AVX2 agree on the argmin even though their dot bits differ — and
/// inside the duplicate group the tie is exact in both kinds, so both
/// must return the **lowest index of the group**, for every panel size
/// (including ones that split the group across panels).
#[test]
fn tie_rule_lowest_index_across_kinds_and_panels() {
    check_with(
        Config {
            cases: 30,
            ..Config::default()
        },
        "duplicate-row ties break to lowest index",
        |g| {
            let dim = g.usize_in(1, 12);
            let groups = g.usize_in(1, 6);
            let copies = g.usize_in(1, 4);
            let mut rng = Rng::new(g.rng.next_u64());
            // Well-separated group centroids: integer lattice step 8.
            let mut weights = Vec::new();
            for gi in 0..groups {
                let row: Vec<f32> = (0..dim)
                    .map(|d| (gi * 8) as f32 + ((d * 3 + gi) % 5) as f32)
                    .collect();
                for _ in 0..copies {
                    weights.extend_from_slice(&row);
                }
            }
            let nodes = groups * copies;
            let cb = Codebook {
                nodes,
                dim,
                weights,
            };
            let w2 = cb.sq_norms();
            // Each data row = some group's row exactly (zero distance).
            let rows = g.usize_in(1, 20);
            let mut data = Vec::new();
            let mut want = Vec::new();
            for _ in 0..rows {
                let gi = rng.below(groups as u64) as usize;
                data.extend_from_slice(cb.row(gi * copies));
                want.push((gi * copies) as u32); // lowest index of the group
            }
            let kinds: &[SimdKind] = if historical_kind() == SimdKind::Avx2Fma {
                &[SimdKind::Scalar, SimdKind::Avx2Fma]
            } else {
                &[SimdKind::Scalar]
            };
            for &kind in kinds {
                for panel in [1usize, copies, copies + 1, nodes, nodes + 9] {
                    let (bmus, dists) =
                        search_bmus_blocked(&data, dim, &cb, &w2, 2, kind, panel);
                    if bmus != want {
                        return Err(format!(
                            "tie broke wrong ({kind:?}, panel={panel}): {bmus:?} vs {want:?}"
                        ));
                    }
                    // Zero-distance rows reconstruct to a clamped d² that
                    // is zero up to Gram cancellation error (a few ulps
                    // of ||x||², which reaches ~2e4 on this lattice).
                    for &d in &dists {
                        if !(d >= 0.0) || d > 1.0 {
                            return Err(format!("zero-distance row got d²={d}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Sparse kernel: dispatched argmin == verbatim old scalar loop.
// ---------------------------------------------------------------------

/// `simd::argmin_scored` (every kind) is bit-identical to the scalar
/// argmin loop the sparse kernel used to run — including exact ties,
/// all-equal scores, and NaN entries.
#[test]
fn sparse_argmin_matches_old_scalar_loop() {
    fn oracle(w2: &[f32], scores: &[f32]) -> (u32, f32) {
        let (mut best, mut best_score) = (0u32, f32::INFINITY);
        for (n, &dot) in scores.iter().enumerate() {
            let score = 0.5 * w2[n] - dot;
            if score < best_score {
                best_score = score;
                best = n as u32;
            }
        }
        (best, best_score)
    }
    check_with(
        Config {
            cases: 60,
            ..Config::default()
        },
        "argmin_scored == old sparse argmin",
        |g| {
            let n = g.usize_in(1, 64);
            let mut rng = Rng::new(g.rng.next_u64());
            let w2: Vec<f32> = (0..n).map(|_| rng.range_f32(0.0, 4.0)).collect();
            let mut dots = rand_data(&mut rng, n);
            // Seed exact ties and a NaN lane on larger cases.
            if n >= 8 {
                dots[n / 2] = dots[n / 4];
                let tie_w = w2[n / 4];
                let mut w2m = w2.clone();
                w2m[n / 2] = tie_w;
                let want = oracle(&w2m, &dots);
                for kind in [SimdKind::Scalar, simd::dispatch()] {
                    let got = simd::argmin_scored(kind, &w2m, &dots);
                    if got.0 != want.0 || got.1.to_bits() != want.1.to_bits() {
                        return Err(format!("tie case {kind:?}: {got:?} vs {want:?}"));
                    }
                }
            }
            let want = oracle(&w2, &dots);
            for kind in [SimdKind::Scalar, simd::dispatch()] {
                let got = simd::argmin_scored(kind, &w2, &dots);
                if got.0 != want.0 || got.1.to_bits() != want.1.to_bits() {
                    return Err(format!("{kind:?}: {got:?} vs {want:?} (n={n})"));
                }
            }
            Ok(())
        },
    );
}

/// End-to-end: the sparse kernel's BMUs are unchanged by the argmin
/// rewiring (same scores, bit-identical selection).
#[test]
fn sparse_kernel_bmus_match_manual_scores() {
    let mut rng = Rng::new(64);
    let grid = Grid::new(4, 4, GridType::Square, MapType::Planar);
    let cb = Codebook::random_init(16, 10, &mut rng);
    let m = Csr::random(30, 10, 0.3, &mut rng);
    let mut k = somoclu::kernels::sparse_cpu::SparseCpuKernel::new(2);
    let got = k
        .epoch_accumulate(
            DataShard::Sparse(m.view()),
            &cb,
            &grid,
            Neighborhood::gaussian(false),
            1.5,
            1.0,
        )
        .unwrap();
    // Manual oracle: transposed-axpy scores + the old scalar argmin.
    let w2 = cb.sq_norms();
    let mut wt = vec![0.0f32; 10 * 16];
    for n in 0..16 {
        for (c, &v) in cb.row(n).iter().enumerate() {
            wt[c * 16 + n] = v;
        }
    }
    for r in 0..30 {
        let (cols, vals) = m.view().row(r);
        let mut scores = vec![0.0f32; 16];
        for (c, v) in cols.iter().zip(vals) {
            let col = &wt[*c as usize * 16..(*c as usize + 1) * 16];
            for (s, cv) in scores.iter_mut().zip(col) {
                *s = cv.mul_add(*v, *s);
            }
        }
        let (mut best, mut best_score) = (0u32, f32::INFINITY);
        for (n, &dot) in scores.iter().enumerate() {
            let s = 0.5 * w2[n] - dot;
            if s < best_score {
                best_score = s;
                best = n as u32;
            }
        }
        assert_eq!(got.bmus[r], best, "row {r}");
    }
}

// ---------------------------------------------------------------------
// best_two vs the old naive pass + degenerate maps.
// ---------------------------------------------------------------------

/// Old naive top-2 (verbatim pre-refactor `best_two` body, single row).
fn naive_best_two(x: &[f32], cb: &Codebook) -> (usize, usize) {
    let (mut b1, mut d1) = (0usize, f32::INFINITY);
    let (mut b2, mut d2) = (0usize, f32::INFINITY);
    for n in 0..cb.nodes {
        let d = sq_dist(x, cb.row(n));
        if d < d1 {
            b2 = b1;
            d2 = d1;
            b1 = n;
            d1 = d;
        } else if d < d2 {
            b2 = n;
            d2 = d;
        }
    }
    (b1, b2)
}

/// On well-separated data (codebook rows on an integer lattice, data
/// rows offset by 0.25) the Gram-form blocked `best_two` must return
/// exactly the old naive pass's pairs — the margins dwarf the arithmetic
/// difference between `sq_dist` and `||w||²/2 − x·w`.
#[test]
fn best_two_matches_naive_on_separated_data() {
    let (nodes, dim) = (30usize, 6usize);
    let mut weights = Vec::new();
    for n in 0..nodes {
        weights.extend((0..dim).map(|d| (n * 4) as f32 + (d % 3) as f32));
    }
    let cb = Codebook {
        nodes,
        dim,
        weights,
    };
    let mut data = Vec::new();
    for r in 0..45 {
        let target = (r * 7) % nodes;
        data.extend(cb.row(target).iter().map(|v| v + 0.25));
    }
    let got = best_two(&data, dim, &cb, 3);
    for (r, pair) in got.iter().enumerate() {
        let want = naive_best_two(&data[r * dim..(r + 1) * dim], &cb);
        assert_eq!(*pair, want, "row {r}");
    }
}

/// On arbitrary random data the *distances* of the returned pair match
/// the naive pass's top-2 distances (indices may differ only on
/// sub-tolerance near-ties, which is the same freedom the two arithmetic
/// forms always had).
#[test]
fn best_two_distances_match_naive_within_tolerance() {
    check_with(
        Config {
            cases: 25,
            ..Config::default()
        },
        "best_two distances ~ naive top-2 distances",
        |g| {
            let dim = g.usize_in(1, 10);
            let nodes = g.usize_in(2, 40);
            let rows = g.usize_in(1, 25);
            let mut rng = Rng::new(g.rng.next_u64());
            let cb = Codebook {
                nodes,
                dim,
                weights: rand_data(&mut rng, nodes * dim),
            };
            let data = rand_data(&mut rng, rows * dim);
            let got = best_two(&data, dim, &cb, 2);
            for (r, &(b1, b2)) in got.iter().enumerate() {
                if b1 == b2 {
                    return Err(format!("row {r}: b2 == b1 == {b1}"));
                }
                let x = &data[r * dim..(r + 1) * dim];
                let (n1, n2) = naive_best_two(x, &cb);
                let (gd1, gd2) = (sq_dist(x, cb.row(b1)), sq_dist(x, cb.row(b2)));
                let (wd1, wd2) = (sq_dist(x, cb.row(n1)), sq_dist(x, cb.row(n2)));
                let tol = 1e-4 * (1.0 + wd2.abs());
                if (gd1 - wd1).abs() > tol || (gd2 - wd2).abs() > tol {
                    return Err(format!(
                        "row {r}: top-2 dists ({gd1},{gd2}) vs naive ({wd1},{wd2})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn degenerate_maps_te_zero_and_distinct_pairs() {
    // Single-node map: TE defined as 0.
    let grid = Grid::new(1, 1, GridType::Square, MapType::Planar);
    let cb = Codebook::zeros(1, 3);
    let data = vec![1.0f32; 4 * 3];
    assert_eq!(topographic_error(&data, 3, &grid, &cb, 2), 0.0);
    // All-equal distances (zero codebook): pair is (0, 1) on every row.
    let cb = Codebook::zeros(9, 3);
    for pair in best_two(&data, 3, &cb, 2) {
        assert_eq!(pair, (0, 1));
    }
}

// ---------------------------------------------------------------------
// QE f64 accumulation at 1e6-row scale.
// ---------------------------------------------------------------------

/// Property (issue satellite 1): at 1e6 rows the reported QE must sit
/// within f32 rounding of the f64 oracle mean. An f32 running sum is
/// ~1e-5 off at this scale (the sum is ~5e5 by the time ~0.5-sized
/// increments arrive), so the 1e-6 relative bound discriminates the fix
/// from the regression.
#[test]
fn qe_matches_f64_oracle_at_1e6_rows() {
    let rows = 1_000_000usize;
    let mut rng = Rng::new(65);
    let data: Vec<f32> = (0..rows).map(|_| rng.range_f32(0.0, 1.0)).collect();
    let cb = Codebook::zeros(1, 1);
    let bmus = vec![0usize; rows];
    let got = quantization_error(&data, 1, &cb, &bmus) as f64;
    let oracle: f64 = data
        .iter()
        .map(|v| sq_dist(std::slice::from_ref(v), &[0.0]).sqrt() as f64)
        .sum::<f64>()
        / rows as f64;
    let rel = (got - oracle).abs() / oracle;
    assert!(rel < 1e-6, "QE {got} vs f64 oracle {oracle} (rel {rel})");
}
