//! Property suite for the cluster collectives (star / ring / tree /
//! auto): summation exactness across rank counts and buffer lengths
//! (including `len < P` and `len % P != 0`), gather order, per-rank
//! byte costs against the closed forms, end-to-end training
//! equivalence between algorithms, and clean peer-loss errors.

use somoclu::cluster::allreduce::{
    allreduce_f32_sum, allreduce_f64_sum_with, barrier_with, gather_u32_with,
    segment_ranges,
};
use somoclu::cluster::comm::{CollectiveAlgo, Endpoint, World};
use somoclu::cluster::netmodel::NetModel;
use somoclu::cluster::runner::ClusterData;
use somoclu::coordinator::config::TrainConfig;
use somoclu::data;
use somoclu::session::Som;
use somoclu::util::rng::Rng;
use somoclu::util::threadpool::run_concurrent;

const ALGOS: [CollectiveAlgo; 4] = [
    CollectiveAlgo::Star,
    CollectiveAlgo::Ring,
    CollectiveAlgo::Tree,
    CollectiveAlgo::Auto,
];

/// Run `task` once per rank of a fresh in-process world and hand back
/// the per-rank outcomes plus the world (for its traffic stats).
fn run_world<T, F>(size: usize, task: F) -> (Vec<T>, World)
where
    T: Send,
    F: Fn(Endpoint) -> T + Send + Sync,
{
    let mut world = World::new(size, NetModel::ideal());
    let eps = world.take_endpoints();
    let task = &task;
    let outs = run_concurrent(eps.into_iter().map(|ep| move || task(ep)).collect());
    (outs, world)
}

#[test]
fn allreduce_exact_for_every_rank_count_and_length() {
    for size in [1usize, 2, 3, 4, 5, 8, 16] {
        // Deliberately includes len < size and len % size != 0.
        let lens = [1usize, 2, size.saturating_sub(1).max(1), size, 3 * size + 1];
        for len in lens {
            for algo in ALGOS {
                let (outs, _) = run_world(size, |mut ep| {
                    // Integer-valued f32s sum exactly (well under 2^24),
                    // so equality is bitwise, not approximate.
                    let mut buf: Vec<f32> =
                        (0..len).map(|i| ((ep.rank + 1) * (i + 3)) as f32).collect();
                    allreduce_f32_sum(&mut ep, &mut buf, algo).unwrap();
                    let scalar =
                        allreduce_f64_sum_with(&mut ep, (ep.rank * ep.rank + 7) as f64, algo)
                            .unwrap();
                    (buf, scalar)
                });
                let rank_sum: usize = (1..=size).sum();
                let want_buf: Vec<f32> =
                    (0..len).map(|i| (rank_sum * (i + 3)) as f32).collect();
                let want_scalar: f64 =
                    (0..size).map(|r| (r * r + 7) as f64).sum();
                for (rank, (buf, scalar)) in outs.iter().enumerate() {
                    assert_eq!(
                        buf, &want_buf,
                        "algo {algo:?} size {size} len {len} rank {rank}"
                    );
                    assert_eq!(
                        *scalar, want_scalar,
                        "algo {algo:?} size {size} len {len} rank {rank}"
                    );
                }
            }
        }
    }
}

#[test]
fn allreduce_results_bit_identical_on_every_rank() {
    // Non-integer values: ranks may disagree only if an implementation
    // let different ranks reduce in different orders. All algorithms
    // fix one global order, so results are bit-identical across ranks.
    for size in [2usize, 3, 4, 5, 8] {
        for algo in ALGOS {
            let (outs, _) = run_world(size, |mut ep| {
                let mut buf: Vec<f32> = (0..17)
                    .map(|i| 0.1 + ep.rank as f32 * 0.7 + i as f32 * 1e-3)
                    .collect();
                allreduce_f32_sum(&mut ep, &mut buf, algo).unwrap();
                buf.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
            });
            for (rank, out) in outs.iter().enumerate().skip(1) {
                assert_eq!(out, &outs[0], "algo {algo:?} size {size} rank {rank}");
            }
        }
    }
}

#[test]
fn gather_matches_star_everywhere() {
    for size in [1usize, 2, 3, 5, 8] {
        let mut per_algo: Vec<Vec<Option<Vec<u32>>>> = Vec::new();
        for algo in ALGOS {
            let (outs, _) = run_world(size, |mut ep| {
                // Variable-length local slices — rank r contributes
                // r + 1 items, so order AND framing both matter.
                let local: Vec<u32> =
                    (0..ep.rank + 1).map(|i| (ep.rank * 100 + i) as u32).collect();
                gather_u32_with(&mut ep, &local, algo).unwrap()
            });
            per_algo.push(outs);
        }
        let want: Vec<u32> = (0..size)
            .flat_map(|r| (0..r + 1).map(move |i| (r * 100 + i) as u32))
            .collect();
        for (algo, outs) in ALGOS.iter().zip(&per_algo) {
            assert_eq!(
                outs[0].as_deref(),
                Some(want.as_slice()),
                "algo {algo:?} size {size}"
            );
            for (rank, out) in outs.iter().enumerate().skip(1) {
                assert!(out.is_none(), "algo {algo:?} size {size} rank {rank}");
            }
        }
    }
}

#[test]
fn ring_per_rank_bytes_match_closed_form() {
    for (size, len) in [(2usize, 64usize), (4, 64), (8, 64), (4, 64 + 3), (8, 5)] {
        let (_, world) = run_world(size, |mut ep| {
            let mut buf = vec![1.0f32; len];
            allreduce_f32_sum(&mut ep, &mut buf, CollectiveAlgo::Ring).unwrap();
        });
        let total_bytes = (4 * len) as u64;
        let segs = segment_ranges(len, size);
        for rank in 0..size {
            // Rank r sends every segment except (r+1)%P twice-skipped:
            // 2·total − seg(r+1) − seg(r+2) bytes, which is exactly
            // 2·(P−1)/P·M when P divides the length.
            let want = 2 * total_bytes
                - 4 * segs[(rank + 1) % size].len() as u64
                - 4 * segs[(rank + 2) % size].len() as u64;
            assert_eq!(
                world.stats.rank_bytes(rank),
                want,
                "size {size} len {len} rank {rank}"
            );
            if len % size == 0 {
                assert_eq!(want, 2 * (size as u64 - 1) * total_bytes / size as u64);
            }
        }
    }
}

#[test]
fn ring_flattens_the_star_bottleneck() {
    // The point of the exercise: the busiest sender under ring moves
    // ~2·(P−1)/P·M while star's root moves (P−1)·M — a ratio of ~2/P
    // on the allreduce payloads (the acceptance gate checks ≤ 0.75 at
    // P = 4 end-to-end; here the collective in isolation).
    let len = 4096usize; // 16 KiB payload: auto would pick ring too
    for size in [2usize, 4, 8] {
        let mut max_by_algo = Vec::new();
        for algo in [CollectiveAlgo::Star, CollectiveAlgo::Ring] {
            let (_, world) = run_world(size, |mut ep| {
                let mut buf = vec![0.5f32; len];
                allreduce_f32_sum(&mut ep, &mut buf, algo).unwrap();
                barrier_with(&mut ep, algo).unwrap();
            });
            max_by_algo.push(world.stats.max_rank_bytes() as f64);
        }
        let ratio = max_by_algo[1] / max_by_algo[0];
        assert!(
            ratio <= 0.75,
            "size {size}: ring busiest-sender {} vs star {} (ratio {ratio:.3})",
            max_by_algo[1],
            max_by_algo[0]
        );
    }
}

fn train_cfg(ranks: usize, algo: CollectiveAlgo) -> TrainConfig {
    TrainConfig {
        rows: 8,
        cols: 8,
        epochs: 5,
        threads: 1,
        ranks,
        radius0: Some(4.0),
        collective: algo,
        ..Default::default()
    }
}

#[test]
fn training_equivalent_across_collectives() {
    let mut rng = Rng::new(77);
    let (d, _) = data::gaussian_blobs(90, 6, 4, 0.2, &mut rng);
    for ranks in [2usize, 4, 5] {
        // 90 rows over 4 or 5 ranks: uneven shards ride along.
        let mut results = Vec::new();
        for algo in ALGOS {
            let (res, report) = Som::builder()
                .config(train_cfg(ranks, algo))
                .build()
                .unwrap()
                .fit_cluster(ClusterData::Dense {
                    data: d.clone(),
                    dim: 6,
                })
                .unwrap();
            assert!(report.bytes_sent > 0);
            results.push((algo, res));
        }
        let (_, star) = &results[0];
        for (algo, res) in &results[1..] {
            // BMUs must agree exactly; codebooks may differ in the last
            // ulps from f32 reassociation, bounded by 5e-4.
            assert_eq!(res.bmus, star.bmus, "ranks {ranks} algo {algo:?}");
            let worst = res
                .codebook
                .weights
                .iter()
                .zip(&star.codebook.weights)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                worst <= 5e-4,
                "ranks {ranks} algo {algo:?}: max codebook delta {worst}"
            );
        }
    }
}

#[test]
fn dead_peer_is_a_clean_error() {
    let mut world = World::new(3, NetModel::ideal());
    let mut eps = world.take_endpoints();
    let dead = eps.remove(1); // rank 1 exits before the collective
    drop(dead);
    let errs = run_concurrent(
        eps.into_iter()
            .map(|mut ep| {
                move || {
                    let mut buf = vec![1.0f32; 8];
                    allreduce_f32_sum(&mut ep, &mut buf, CollectiveAlgo::Ring).err()
                }
            })
            .collect(),
    );
    let msgs: Vec<String> = errs.into_iter().flatten().map(|e| e.to_string()).collect();
    assert!(!msgs.is_empty());
    for m in &msgs {
        assert!(m.contains("rank 1 lost"), "unhelpful error: {m}");
    }
}
