//! Integration: the accel (XLA/PJRT) kernel across the full training
//! loop — the three-layer path (rust -> HLO artifact -> Pallas kernels).
//!
//! Requires `make artifacts`; tests skip (with a message) if absent so
//! `cargo test` stays usable before the AOT step.

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::session::Som;
use somoclu::runtime::Manifest;
use somoclu::som::{GridType, MapType, Neighborhood};
use somoclu::util::rng::Rng;

fn artifacts_available() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

/// Single-process training through the session API.
fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
}


fn accel_cfg() -> TrainConfig {
    TrainConfig {
        rows: 10,
        cols: 10,
        epochs: 6,
        kernel: KernelType::Accel,
        threads: 2,
        radius0: Some(5.0),
        ..Default::default()
    }
}

#[test]
fn accel_full_training_converges() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rng = Rng::new(300);
    let (d, _) = data::gaussian_blobs(256, 12, 4, 0.15, &mut rng);
    let res = fit(&accel_cfg(), DataShard::Dense { data: &d, dim: 12 }).unwrap();
    assert!(
        res.epochs.last().unwrap().qe < res.epochs[0].qe * 0.5,
        "QE: {:?}",
        res.epochs.iter().map(|e| e.qe).collect::<Vec<_>>()
    );
}

#[test]
fn accel_matches_cpu_over_full_run() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // Whole-run comparison: f32 rounding makes long trajectories diverge
    // chaotically (both reach equally good maps), so the contract is
    // (a) exact-ish single-epoch agreement — covered by the kernel-level
    // tests — and (b) end-quality parity here.
    let mut rng = Rng::new(301);
    let (d, _) = data::gaussian_blobs(200, 8, 4, 0.2, &mut rng);
    let shard = DataShard::Dense { data: &d, dim: 8 };
    let mut cpu_cfg = accel_cfg();
    cpu_cfg.kernel = KernelType::DenseCpu;

    let cpu = fit(&cpu_cfg, shard).unwrap();
    let accel = fit(&accel_cfg(), shard).unwrap();

    let qe_rel = (cpu.final_qe() - accel.final_qe()).abs() / cpu.final_qe();
    assert!(qe_rel < 1e-2, "QE diverged: {qe_rel}");
    // Informational floor: most assignments still coincide on blob data.
    let agree = cpu
        .bmus
        .iter()
        .zip(&accel.bmus)
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        agree as f64 >= 0.7 * cpu.bmus.len() as f64,
        "only {agree}/{} BMUs agree",
        cpu.bmus.len()
    );
}

#[test]
fn accel_geometry_variants() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rng = Rng::new(302);
    let (d, _) = data::gaussian_blobs(128, 8, 3, 0.2, &mut rng);
    for (gt, mt, nb) in [
        (GridType::Square, MapType::Toroid, Neighborhood::gaussian(false)),
        (GridType::Hexagonal, MapType::Planar, Neighborhood::gaussian(true)),
        (GridType::Hexagonal, MapType::Toroid, Neighborhood::bubble()),
    ] {
        let cfg = TrainConfig {
            rows: 8,
            cols: 8,
            epochs: 3,
            kernel: KernelType::Accel,
            grid_type: gt,
            map_type: mt,
            neighborhood: nb,
            threads: 1,
            radius0: Some(4.0),
            ..Default::default()
        };
        let res = fit(&cfg, DataShard::Dense { data: &d, dim: 8 }).unwrap();
        assert!(
            res.final_qe().is_finite(),
            "{gt:?}/{mt:?}/{nb:?} produced non-finite QE"
        );
    }
}

#[test]
fn accel_selects_larger_artifact_for_bigger_maps() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(Manifest::default_dir()).unwrap();
    // 50x50 map (2500 nodes) must route past "tiny"/"small" to a config
    // with n >= 2500.
    let art = manifest
        .select_som_step("gaussian", "planar", 100, 2500)
        .unwrap();
    assert!(art.n >= 2500, "{art:?}");
    // 16-dim small map routes to the smallest config.
    let art = manifest.select_som_step("gaussian", "planar", 16, 256).unwrap();
    assert_eq!(art.shape, "tiny");
}

#[test]
fn umatrix_artifact_matches_cpu_umatrix() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    use somoclu::runtime::{umatrix_accel, Engine};
    let mut rng = Rng::new(303);
    for (gt, mt) in [
        (GridType::Square, MapType::Planar),
        (GridType::Square, MapType::Toroid),
        (GridType::Hexagonal, MapType::Planar),
    ] {
        let grid = somoclu::som::Grid::new(10, 12, gt, mt);
        let cb = somoclu::som::Codebook::random_init(120, 12, &mut rng);
        let cpu = somoclu::som::umatrix::umatrix(&grid, &cb, 2);
        let mut engine = Engine::from_env().unwrap();
        let acc = umatrix_accel(&mut engine, &grid, &cb).unwrap();
        assert_eq!(acc.len(), cpu.len());
        for (i, (a, b)) in acc.iter().zip(&cpu).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 + 1e-4 * b.abs(),
                "{gt:?}/{mt:?} node {i}: {a} vs {b}"
            );
        }
    }
}
