//! Chaos suite for the fault-tolerance layer (ISSUE 10): deterministic
//! fault injection ([`somoclu::cluster::FaultPlan`]) against the
//! in-process cluster runner across every collective algorithm, plus a
//! real-process SIGKILL-and-rejoin smoke over loopback TCP.
//!
//! The core property everywhere: a run that loses a rank and recovers
//! under a [`RecoveryPolicy`] finishes **byte-identical** to a run that
//! never faulted — same BMUs, same codebook bits. Fault positions are
//! derived from a clean observation probe (operation counts are a pure
//! function of (collective, rank count, schedule)), so every scenario
//! here is reproducible, never a flake.

use std::sync::Arc;
use std::time::Duration;

use somoclu::cluster::comm::CollectiveAlgo;
use somoclu::cluster::runner::ClusterData;
use somoclu::cluster::{FaultPlan, RecoveryPolicy};
use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::session::Som;
use somoclu::util::rng::Rng;

const RANKS: usize = 3;
const EPOCHS: usize = 3;
const DIM: usize = 4;

fn cfg(collective: CollectiveAlgo) -> TrainConfig {
    TrainConfig {
        rows: 6,
        cols: 6,
        epochs: EPOCHS,
        threads: 1,
        ranks: RANKS,
        radius0: Some(3.0),
        collective,
        ..Default::default()
    }
}

fn blobs() -> Vec<f32> {
    let mut rng = Rng::new(42);
    data::gaussian_blobs(60, DIM, 3, 0.2, &mut rng).0
}

/// One in-process cluster run under `plan` (None = no injection) and
/// `policy`, returning the final result.
fn run(
    collective: CollectiveAlgo,
    plan: Option<Arc<FaultPlan>>,
    policy: RecoveryPolicy,
) -> Result<TrainResult, somoclu::error::SomError> {
    let mut session = Som::builder()
        .config(cfg(collective))
        .recovery(policy)
        .build()?;
    session.set_fault_plan(plan);
    session
        .fit_cluster(ClusterData::Dense {
            data: blobs(),
            dim: DIM,
        })
        .map(|(res, _)| res)
}

/// Clean reference run plus per-rank total operation counts (the probe
/// that lets kill positions be aimed by arithmetic).
fn probe(collective: CollectiveAlgo) -> (TrainResult, Vec<u64>) {
    let plan = Arc::new(FaultPlan::observe(RANKS));
    let clean = run(collective, Some(plan.clone()), RecoveryPolicy::none()).unwrap();
    let totals = (0..RANKS).map(|r| plan.ops(r)).collect();
    (clean, totals)
}

fn assert_identical(a: &TrainResult, b: &TrainResult, what: &str) {
    assert_eq!(a.bmus, b.bmus, "{what}: BMUs diverged");
    assert_eq!(
        a.codebook.weights, b.codebook.weights,
        "{what}: codebook bits diverged"
    );
}

const COLLECTIVES: [(CollectiveAlgo, &str); 3] = [
    (CollectiveAlgo::Star, "star"),
    (CollectiveAlgo::Ring, "ring"),
    (CollectiveAlgo::Tree, "tree"),
];

/// The property sweep: for every collective algorithm, kill every rank
/// at operation positions spanning the whole run (early / middle / late
/// — with a 3-epoch schedule that is one kill per epoch, plus the final
/// gather region). Every scenario must recover byte-identical to the
/// clean run within one restart.
#[test]
fn killing_any_rank_anywhere_recovers_byte_identical() {
    for (algo, name) in COLLECTIVES {
        let (clean, totals) = probe(algo);
        for victim in 0..RANKS {
            for sixth in [1, 3, 5] {
                let at_op = totals[victim] * sixth / 6;
                let plan = Arc::new(FaultPlan::observe(RANKS).kill(victim, at_op));
                let tag = format!("{name}: kill rank {victim} at op {at_op}");
                let recovered = run(
                    algo,
                    Some(plan.clone()),
                    RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)),
                )
                .unwrap_or_else(|e| panic!("{tag}: did not recover: {e}"));
                assert!(plan.all_fired(), "{tag}: the kill never triggered");
                assert_identical(&clean, &recovered, &tag);
            }
        }
    }
}

/// Seeded pseudo-random kills: a seed IS a reproducible failure
/// scenario, so a handful of seeds both exercises arbitrary positions
/// and stays deterministic run-to-run.
#[test]
fn seeded_kills_recover() {
    let (clean, totals) = probe(CollectiveAlgo::Star);
    let max_op = *totals.iter().min().unwrap();
    for seed in [1u64, 7, 23] {
        let plan = Arc::new(FaultPlan::seeded_kill(seed, RANKS, max_op));
        let recovered = run(
            CollectiveAlgo::Star,
            Some(plan.clone()),
            RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: did not recover: {e}"));
        assert!(plan.all_fired(), "seed {seed}: the kill never triggered");
        assert_identical(&clean, &recovered, &format!("seed {seed}"));
    }
}

/// A stalled peer (delay fault) is not a failure: the run completes
/// without spending any restart — recovery disabled on purpose — and
/// the result is still byte-identical.
#[test]
fn delayed_peer_is_benign() {
    let (clean, _) = probe(CollectiveAlgo::Ring);
    let plan =
        Arc::new(FaultPlan::observe(RANKS).delay(1, 9, Duration::from_millis(50)));
    let delayed = run(CollectiveAlgo::Ring, Some(plan.clone()), RecoveryPolicy::none())
        .expect("a delay must not abort the run");
    assert!(plan.all_fired());
    assert_identical(&clean, &delayed, "delay");
}

/// A torn frame surfaces as a typed `CommError::Protocol` on the
/// receiving side (every collective decode validates payload length)
/// and feeds the same abort/recovery path as a lost rank. If the torn
/// operation happens to be a receive the fault is a no-op by design —
/// either way the run must end byte-identical.
#[test]
fn torn_frame_recovers_byte_identical() {
    let (clean, totals) = probe(CollectiveAlgo::Star);
    let plan = Arc::new(FaultPlan::observe(RANKS).torn_frame(0, totals[0] / 2));
    let recovered = run(
        CollectiveAlgo::Star,
        Some(plan.clone()),
        RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)),
    )
    .expect("torn frame must recover or pass through");
    assert!(plan.all_fired());
    assert_identical(&clean, &recovered, "torn frame");
}

/// More kills than budget: the run must fail with the typed `recovery`
/// error code and name the failed rank — never hang, never panic.
/// Kills at consecutive op indices fire once per attempt (op counters
/// are cumulative across world re-formations).
#[test]
fn exhausted_budget_is_a_typed_recovery_error() {
    let (_, totals) = probe(CollectiveAlgo::Star);
    let at = totals[1] / 2;
    let plan = Arc::new(
        FaultPlan::observe(RANKS)
            .kill(1, at)
            .kill(1, at + 1)
            .kill(1, at + 2)
            .kill(1, at + 3),
    );
    let err = run(
        CollectiveAlgo::Star,
        Some(plan),
        RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)),
    )
    .expect_err("budget of 2 cannot outlive 4 kills");
    assert_eq!(err.code(), "recovery", "{err}");
    let msg = err.to_string();
    assert!(msg.contains("rank 1"), "{msg}");
}

/// With recovery off (the default), the first loss keeps the historical
/// `comm` error code, and the message points at the `--recover` flag.
#[test]
fn recovery_disabled_keeps_the_comm_code() {
    let (_, totals) = probe(CollectiveAlgo::Star);
    let plan = Arc::new(FaultPlan::observe(RANKS).kill(2, totals[2] / 2));
    let err = run(CollectiveAlgo::Star, Some(plan), RecoveryPolicy::none())
        .expect_err("no recovery: first loss is fatal");
    assert_eq!(err.code(), "comm", "{err}");
    assert!(err.to_string().contains("--recover"), "{err}");
}

// ---------------------------------------------------------------------
// Real processes: SIGKILL a rank mid-run, relaunch it, recover.
// ---------------------------------------------------------------------

#[cfg(unix)]
mod sigkill {
    use std::path::{Path, PathBuf};
    use std::process::{Child, Command, Stdio};

    fn bin() -> PathBuf {
        let mut p = std::env::current_exe().unwrap();
        p.pop(); // deps/
        p.pop(); // <profile>/
        p.push("somoclu");
        p
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_chaos_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn free_port() -> u16 {
        std::net::TcpListener::bind("127.0.0.1:0")
            .unwrap()
            .local_addr()
            .unwrap()
            .port()
    }

    const TRAIN_ARGS: [&str; 12] = [
        "-e", "30", "-x", "7", "-y", "7", "-r", "3", "--threads", "1", "--seed", "99",
    ];

    fn spawn_rank(input: &Path, prefix: &Path, extra: &[&str]) -> Child {
        Command::new(bin())
            .args(TRAIN_ARGS)
            .args(extra)
            .arg(input.to_str().unwrap())
            .arg(prefix.to_str().unwrap())
            .env("SOMOCLU_BOOTSTRAP_TIMEOUT_SECS", "60")
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("binary spawns")
    }

    fn finish(child: Child, who: &str) -> (bool, String) {
        let out = child.wait_with_output().expect("process completes");
        (
            out.status.success(),
            format!("{who} stderr:\n{}", String::from_utf8_lossy(&out.stderr)),
        )
    }

    /// Kill rank 1 with SIGKILL once training has demonstrably passed
    /// the epoch-2 checkpoint, relaunch it, and require the recovered
    /// 2-process run to be byte-identical to the simulated 2-rank run.
    #[test]
    fn sigkill_and_rejoin_matches_clean_run() {
        let dir = tmpdir("rejoin");
        let input = dir.join("data.txt");
        {
            let mut rng = somoclu::util::rng::Rng::new(600);
            let (d, _) = somoclu::data::gaussian_blobs(80, 5, 3, 0.2, &mut rng);
            somoclu::io::dense::write_dense(&input, 80, 5, &d, false).unwrap();
        }

        // Clean reference: the simulated in-process 2-rank run.
        let sim_prefix = dir.join("sim");
        let out = Command::new(bin())
            .args(TRAIN_ARGS)
            .args(["--ranks", "2"])
            .arg(input.to_str().unwrap())
            .arg(sim_prefix.to_str().unwrap())
            .output()
            .expect("binary runs");
        assert!(
            out.status.success(),
            "simulated run: {}",
            String::from_utf8_lossy(&out.stderr)
        );

        let peers = format!("127.0.0.1:{},127.0.0.1:{}", free_port(), free_port());
        let prefix0 = dir.join("net0");
        let prefix1 = dir.join("net1");
        let common = [
            "--ranks", "2", "--peers", peers.as_str(),
            "--checkpoint-every", "2", "--recover", "max-restarts=3",
        ];
        let rank_args = |rank: &'static str, common: &[&str]| {
            let mut v = vec!["--rank", rank];
            v.extend_from_slice(common);
            v
        };
        let r0 = spawn_rank(&input, &prefix0, &rank_args("0", &common));
        let mut r1 = spawn_rank(&input, &prefix1, &rank_args("1", &common));

        // Rank 0 owns checkpoints: once <prefix0>.epoch2.somc exists the
        // cluster is provably mid-run (epoch 2 of 30) — SIGKILL rank 1.
        let ck = somoclu::session::checkpoint_path(prefix0.to_str().unwrap(), 2);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        while !ck.exists() {
            assert!(
                std::time::Instant::now() < deadline,
                "epoch-2 checkpoint never appeared"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        r1.kill().expect("SIGKILL rank 1");
        let _ = r1.wait();

        // The replacement rank re-binds rank 1's port, re-rendezvous,
        // adopts the window header, and the run completes.
        let r1b = spawn_rank(&input, &prefix1, &rank_args("1", &common));
        let (ok0, err0) = finish(r0, "rank 0");
        let (ok1, err1) = finish(r1b, "replacement rank 1");
        assert!(ok0, "{err0}");
        assert!(ok1, "{err1}");

        for ext in [".wts", ".bm"] {
            let sim = std::fs::read(format!("{}{ext}", sim_prefix.display())).unwrap();
            let net = std::fs::read(format!("{}{ext}", prefix0.display())).unwrap();
            assert_eq!(sim, net, "{ext} differs after SIGKILL-and-rejoin");
        }
    }
}
