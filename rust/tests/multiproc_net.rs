//! End-to-end through the real binary, **as real processes**: N
//! `somoclu` processes rendezvous over loopback sockets and train one
//! map, and the outputs must be byte-identical to the simulated
//! in-process `--ranks N` run — the same collectives run over the
//! channel mesh and the socket transport.

use std::path::{Path, PathBuf};
use std::process::{Child, Command};

use somoclu::data;
use somoclu::io::dense;
use somoclu::util::rng::Rng;

fn bin() -> PathBuf {
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("somoclu");
    p
}

fn tmpdir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("somoclu_net_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_input(dir: &Path, seed: u64) -> PathBuf {
    let mut rng = Rng::new(seed);
    let (d, _) = data::gaussian_blobs(80, 5, 3, 0.2, &mut rng);
    let input = dir.join("data.txt");
    dense::write_dense(&input, 80, 5, &d, false).unwrap();
    input
}

/// Pick a loopback port by binding to :0 and releasing it.
fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

const TRAIN_ARGS: [&str; 12] = [
    "-e", "4", "-x", "7", "-y", "7", "-r", "3", "--threads", "1", "--seed", "99",
];

fn spawn_rank(input: &Path, prefix: &Path, extra: &[&str]) -> Child {
    Command::new(bin())
        .args(TRAIN_ARGS)
        .args(extra)
        .arg(input.to_str().unwrap())
        .arg(prefix.to_str().unwrap())
        .env("SOMOCLU_BOOTSTRAP_TIMEOUT_SECS", "60")
        .output_piped()
}

trait Piped {
    fn output_piped(&mut self) -> Child;
}
impl Piped for Command {
    fn output_piped(&mut self) -> Child {
        self.stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("binary spawns")
    }
}

fn finish(child: Child, who: &str) -> (bool, String) {
    let out = child.wait_with_output().expect("process completes");
    (
        out.status.success(),
        format!("{who} stderr:\n{}", String::from_utf8_lossy(&out.stderr)),
    )
}

fn read_bytes(p: &str) -> Vec<u8> {
    std::fs::read(p).unwrap_or_else(|e| panic!("{p}: {e}"))
}

#[test]
fn two_process_tcp_matches_simulated_two_rank_run() {
    let dir = tmpdir("tcp2");
    let input = write_input(&dir, 600);

    // Reference: the simulated in-process 2-rank run.
    let sim_prefix = dir.join("sim");
    let out = Command::new(bin())
        .args(TRAIN_ARGS)
        .args(["--ranks", "2"])
        .arg(input.to_str().unwrap())
        .arg(sim_prefix.to_str().unwrap())
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "simulated run: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // Two real processes over loopback TCP via the shorthand flags.
    let addr = format!("127.0.0.1:{}", free_port());
    let net_prefix = dir.join("net");
    let peer_prefix = dir.join("peer");
    let r0 = spawn_rank(&input, &net_prefix, &["--listen", &addr]);
    let r1 = spawn_rank(&input, &peer_prefix, &["--connect", &addr]);
    let (ok0, err0) = finish(r0, "rank 0");
    let (ok1, err1) = finish(r1, "rank 1");
    assert!(ok0, "{err0}");
    assert!(ok1, "{err1}");

    // Rank 0 writes the outputs, byte-identical to the simulated run.
    for ext in [".wts", ".bm"] {
        let sim = read_bytes(&format!("{}{ext}", sim_prefix.display()));
        let net = read_bytes(&format!("{}{ext}", net_prefix.display()));
        assert_eq!(sim, net, "{ext} differs between simulated and 2-process runs");
    }
    // Rank 1 writes nothing.
    for ext in [".wts", ".bm", ".umx"] {
        assert!(
            !std::path::Path::new(&format!("{}{ext}", peer_prefix.display())).exists(),
            "rank 1 must not write {ext}"
        );
    }
    assert!(err1.contains("written by rank 0"), "{err1}");
}

#[test]
fn three_process_tcp_explicit_rank_form() {
    let dir = tmpdir("tcp3");
    let input = write_input(&dir, 601);

    let sim_prefix = dir.join("sim");
    let out = Command::new(bin())
        .args(TRAIN_ARGS)
        .args(["--ranks", "3", "--collective", "ring"])
        .arg(input.to_str().unwrap())
        .arg(sim_prefix.to_str().unwrap())
        .output()
        .expect("binary runs");
    assert!(
        out.status.success(),
        "simulated run: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let peers = format!("127.0.0.1:{},127.0.0.1:{}", free_port(), free_port());
    let mut children = Vec::new();
    for rank in 0..3usize {
        let prefix = dir.join(format!("net{rank}"));
        let rank_s = rank.to_string();
        children.push(spawn_rank(
            &input,
            &prefix,
            &[
                "--ranks", "3", "--rank", &rank_s, "--peers", &peers,
                "--collective", "ring",
            ],
        ));
    }
    for (rank, child) in children.into_iter().enumerate() {
        let (ok, err) = finish(child, &format!("rank {rank}"));
        assert!(ok, "{err}");
    }
    for ext in [".wts", ".bm"] {
        let sim = read_bytes(&format!("{}{ext}", sim_prefix.display()));
        let net = read_bytes(&format!("{}{ext}", dir.join("net0").display()));
        assert_eq!(sim, net, "{ext} differs at 3 ranks over ring");
    }
}

#[cfg(unix)]
#[test]
fn two_process_unix_socket_roundtrip() {
    let dir = tmpdir("uds");
    let input = write_input(&dir, 602);
    let addr = format!("unix:{}", dir.join("rank0.sock").display());
    let r0 = spawn_rank(&input, &dir.join("a"), &["--listen", &addr]);
    let r1 = spawn_rank(&input, &dir.join("b"), &["--connect", &addr]);
    let (ok0, err0) = finish(r0, "rank 0");
    let (ok1, err1) = finish(r1, "rank 1");
    assert!(ok0, "{err0}");
    assert!(ok1, "{err1}");
    assert!(
        std::path::Path::new(&format!("{}.wts", dir.join("a").display())).exists(),
        "{err0}"
    );
}

#[test]
fn mismatched_schedule_refused_at_bootstrap() {
    let dir = tmpdir("mismatch");
    let input = write_input(&dir, 603);
    let addr = format!("127.0.0.1:{}", free_port());
    // Rank 1 trains a different schedule: the handshake fingerprint
    // must refuse the pairing instead of training a corrupted map.
    let r0 = spawn_rank(&input, &dir.join("a"), &["--listen", &addr]);
    let r1 = Command::new(bin())
        .args(["-e", "9", "-x", "7", "-y", "7", "-r", "3", "--threads", "1"])
        .args(["--connect", &addr])
        .arg(input.to_str().unwrap())
        .arg(dir.join("b").to_str().unwrap())
        .env("SOMOCLU_BOOTSTRAP_TIMEOUT_SECS", "60")
        .output_piped();
    let (ok0, err0) = finish(r0, "rank 0");
    let (ok1, err1) = finish(r1, "rank 1");
    assert!(!ok0 && !ok1, "mismatched configs must not both succeed");
    assert!(
        err0.contains("fingerprint") || err1.contains("fingerprint"),
        "{err0}\n{err1}"
    );
}
