//! End-to-end tests of the `somoclu serve` daemon (ISSUE 8):
//!
//! * served `bmu`/`project` answers are **bit-identical** to offline
//!   [`SomSession`] answers, including under ≥ 8 concurrent clients;
//! * hot swap is atomic under load: while a training job publishes a
//!   new map, every answer matches exactly the old map or the new one,
//!   and every projected batch is entirely one map's answer (no torn
//!   reads);
//! * graceful shutdown drains: a running job checkpoints, re-queues in
//!   the journal, and a fresh daemon on the same state dir resumes it
//!   from where it stopped (not epoch 0);
//! * malformed and version-mismatched requests are rejected with typed
//!   `protocol` errors before (hello) or at (frame) the parse boundary.
//!
//! Everything binds `127.0.0.1:0` (or a unix socket) so tests run in
//! parallel without port clashes.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use somoclu::api::DataInput;
use somoclu::coordinator::config::TrainConfig;
use somoclu::serve::{Client, DaemonHandle, JobEvent, Response, ServeOptions, VERSION};
use somoclu::session::{Som, SomSession};
use somoclu::util::rng::Rng;

const DIM: usize = 6;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "somoclu-serve-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn blob_data(seed: u64, rows: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    somoclu::data::gaussian_blobs(rows, DIM, 4, 0.2, &mut rng).0
}

fn small_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        rows: 7,
        cols: 7,
        epochs,
        threads: 2,
        radius0: Some(3.0),
        ..Default::default()
    }
}

/// Train a small map offline and checkpoint it into `dir`.
fn make_checkpoint(dir: &Path, tag: &str, seed: u64, epochs: usize) -> PathBuf {
    let data = blob_data(seed, 120);
    let mut s = Som::builder().config(small_cfg(epochs)).build().unwrap();
    s.fit(DataInput::BorrowedF32 { data: &data, dim: DIM }).unwrap();
    let ck = dir.join(format!("{tag}.somc"));
    s.save_checkpoint(&ck).unwrap();
    ck
}

fn offline(ck: &Path) -> SomSession {
    let mut s = Som::resume(ck).unwrap();
    s.set_threads(2);
    s
}

fn serve_opts(dir: &Path, ck: Option<&Path>) -> ServeOptions {
    let mut opts = ServeOptions::new(dir.join("state"));
    opts.checkpoint = ck.map(Path::to_path_buf);
    opts.threads = 2;
    opts
}

/// Per-query offline reference: `(node, distance bits)`.
fn offline_bmus(session: &SomSession, queries: &[f32]) -> Vec<(usize, u32)> {
    queries
        .chunks(DIM)
        .map(|x| {
            let (node, d) = session.bmu(x).unwrap();
            (node, d.to_bits())
        })
        .collect()
}

// ---------------------------------------------------------------------
// Concurrent-client bit-equality
// ---------------------------------------------------------------------

/// ≥ 8 concurrent clients; every `bmu` and `project` answer must be
/// bit-identical to the offline session over the same checkpoint.
#[test]
fn concurrent_clients_match_offline_answers() {
    let dir = tmpdir("concurrent");
    let ck = make_checkpoint(&dir, "map", 11, 6);
    let daemon = DaemonHandle::spawn(serve_opts(&dir, Some(&ck))).unwrap();
    let addr = daemon.addr().to_string();

    let queries = Arc::new(blob_data(99, 32)); // held-out data
    let mut off = offline(&ck);
    let want_bmus = Arc::new(offline_bmus(&off, &queries));
    let want_project = Arc::new(
        off.project(DataInput::BorrowedF32 { data: &queries, dim: DIM }).unwrap(),
    );

    let threads: Vec<_> = (0..8)
        .map(|_| {
            let (addr, queries) = (addr.clone(), Arc::clone(&queries));
            let (want_bmus, want_project) =
                (Arc::clone(&want_bmus), Arc::clone(&want_project));
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for _ in 0..5 {
                    for (x, want) in queries.chunks(DIM).zip(want_bmus.iter()) {
                        let (node, d) = c.bmu(x).unwrap();
                        assert_eq!((node, d.to_bits()), *want);
                    }
                    assert_eq!(c.project(DIM, &queries).unwrap(), *want_project);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // Status reflects the served map and counted the load.
    let mut c = Client::connect(&addr).unwrap();
    let st = c.status().unwrap();
    assert_eq!((st.rows, st.cols, st.dim), (7, 7, DIM as u32));
    assert_eq!(st.epoch, 6);
    assert!(st.checkpoint.ends_with("map.somc"), "{}", st.checkpoint);
    assert!(st.requests_served >= 8 * 5 * 33, "{}", st.requests_served);

    // Quality goes through the same offline arithmetic.
    let (qe, te) = c.quality(DIM, &queries).unwrap();
    let bmus: Vec<usize> = want_project.iter().map(|&b| b as usize).collect();
    let cb = off.codebook().unwrap();
    let want_qe = somoclu::som::quality::quantization_error(&queries, DIM, cb, &bmus);
    let want_te =
        somoclu::som::quality::topographic_error(&queries, DIM, off.grid(), cb, 2);
    assert_eq!(qe.to_bits(), want_qe.to_bits());
    assert_eq!(te.to_bits(), want_te.to_bits());

    daemon.stop().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The same bit-equality over a unix-domain socket.
#[cfg(unix)]
#[test]
fn unix_socket_serves_identical_answers() {
    let dir = tmpdir("unix");
    let ck = make_checkpoint(&dir, "map", 12, 5);
    let mut opts = serve_opts(&dir, Some(&ck));
    opts.addr = format!("unix:{}", dir.join("somoclu.sock").display());
    let daemon = DaemonHandle::spawn(opts).unwrap();

    let queries = blob_data(98, 8);
    let want = offline_bmus(&offline(&ck), &queries);
    let mut c = Client::connect(daemon.addr()).unwrap();
    for (x, w) in queries.chunks(DIM).zip(want.iter()) {
        let (node, d) = c.bmu(x).unwrap();
        assert_eq!((node, d.to_bits()), *w);
    }
    drop(c);
    daemon.stop().unwrap();
    // The socket file is removed on drain.
    assert!(!dir.join("somoclu.sock").exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Typed request errors
// ---------------------------------------------------------------------

#[test]
fn typed_errors_for_bad_requests() {
    let dir = tmpdir("typed-errors");

    // An empty daemon answers reads with a `state` error.
    let empty = DaemonHandle::spawn(serve_opts(&dir, None)).unwrap();
    let mut c = Client::connect(empty.addr()).unwrap();
    assert_eq!(c.bmu(&[0.0; DIM]).unwrap_err().code(), "state");
    let st = c.status().unwrap(); // status still answers
    assert_eq!(st.checkpoint, "");
    drop(c);
    empty.stop().unwrap();

    // A serving daemon rejects dimension mismatches with `data`.
    let ck = make_checkpoint(&dir, "map", 13, 4);
    let daemon = DaemonHandle::spawn(serve_opts(&dir, Some(&ck))).unwrap();
    let mut c = Client::connect(daemon.addr()).unwrap();
    assert_eq!(c.bmu(&[0.0; DIM + 1]).unwrap_err().code(), "data");
    assert_eq!(c.project(DIM, &[0.0; DIM + 1]).unwrap_err().code(), "data");
    assert_eq!(c.project(0, &[]).unwrap_err().code(), "data");
    // Bad job submissions fail at submit time with `job`.
    let argv = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    assert_eq!(c.submit(&argv(&["-e", "3"])).unwrap_err().code(), "job");
    assert_eq!(
        c.submit(&argv(&["--ranks", "2", "in", "out"])).unwrap_err().code(),
        "job"
    );
    // Watching an unknown job is a `job` error; the connection survives.
    c.watch(999).unwrap();
    assert_eq!(c.next_event().unwrap_err().code(), "job");
    assert!(c.status().is_ok());
    drop(c);
    daemon.stop().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Protocol-level rejection (raw bytes)
// ---------------------------------------------------------------------

fn read_error_frame(s: &mut TcpStream) -> (String, String) {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).unwrap();
    let mut payload = vec![0u8; u32::from_le_bytes(len) as usize];
    s.read_exact(&mut payload).unwrap();
    match Response::decode(&payload).unwrap() {
        Response::Error { code, message } => (code, message),
        other => panic!("wanted an error frame, got {other:?}"),
    }
}

#[test]
fn malformed_and_mismatched_requests_rejected() {
    let dir = tmpdir("reject");
    let ck = make_checkpoint(&dir, "map", 14, 3);
    let daemon = DaemonHandle::spawn(serve_opts(&dir, Some(&ck))).unwrap();
    let addr = daemon.addr().to_string();

    // Wrong version: rejected before the daemon echoes its hello.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"SOMS").unwrap();
    s.write_all(&(VERSION + 1).to_le_bytes()).unwrap();
    let (code, message) = read_error_frame(&mut s);
    assert_eq!(code, "protocol");
    assert!(message.contains("version"), "{message}");
    // ... and the connection is closed.
    assert_eq!(s.read(&mut [0u8; 1]).unwrap(), 0);

    // Wrong magic.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"HTTP").unwrap();
    s.write_all(&VERSION.to_le_bytes()).unwrap();
    let (code, message) = read_error_frame(&mut s);
    assert_eq!(code, "protocol");
    assert!(message.contains("magic"), "{message}");

    // Good hello, then a frame with an unknown request tag: a typed
    // reject, then close (the stream is no longer trustworthy).
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"SOMS").unwrap();
    s.write_all(&VERSION.to_le_bytes()).unwrap();
    let mut hello = [0u8; 8];
    s.read_exact(&mut hello).unwrap();
    assert_eq!(&hello[..4], b"SOMS");
    s.write_all(&1u32.to_le_bytes()).unwrap(); // frame length 1
    s.write_all(&[0xFF]).unwrap(); // unknown tag
    let (code, _) = read_error_frame(&mut s);
    assert_eq!(code, "protocol");
    assert_eq!(s.read(&mut [0u8; 1]).unwrap(), 0);

    // Good hello, then a truncated Bmu payload (tag only, no vector).
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&somoclu::serve::protocol::MAGIC).unwrap();
    s.write_all(&VERSION.to_le_bytes()).unwrap();
    s.read_exact(&mut hello).unwrap();
    s.write_all(&1u32.to_le_bytes()).unwrap();
    s.write_all(&[1]).unwrap(); // REQ_BMU with missing fields
    let (code, _) = read_error_frame(&mut s);
    assert_eq!(code, "protocol");

    daemon.stop().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Jobs: hot swap under load
// ---------------------------------------------------------------------

fn write_train_file(dir: &Path, seed: u64, rows: usize) -> PathBuf {
    let data = blob_data(seed, rows);
    let path = dir.join(format!("train-{seed}.txt"));
    somoclu::io::dense::write_dense(&path, rows, DIM, &data, false).unwrap();
    path
}

/// Queries answered while a job trains and publishes must each match
/// the old map or the new one exactly — and a projected batch must be
/// entirely one map's answer.
#[test]
fn hot_swap_is_atomic_under_load() {
    let dir = tmpdir("hotswap");
    let ck_a = make_checkpoint(&dir, "a", 21, 5);
    let daemon = DaemonHandle::spawn(serve_opts(&dir, Some(&ck_a))).unwrap();
    let addr = daemon.addr().to_string();

    let queries = Arc::new(blob_data(97, 16));
    let mut off_a = offline(&ck_a);
    let bmus_a = Arc::new(offline_bmus(&off_a, &queries));
    let project_a = Arc::new(
        off_a.project(DataInput::BorrowedF32 { data: &queries, dim: DIM }).unwrap(),
    );

    // 8 load threads record every answer while the job swaps the map.
    let stop = Arc::new(AtomicBool::new(false));
    let seen_bmu: Arc<Mutex<Vec<Vec<(usize, u32)>>>> = Arc::new(Mutex::new(Vec::new()));
    let seen_project: Arc<Mutex<Vec<Vec<u32>>>> = Arc::new(Mutex::new(Vec::new()));
    let load: Vec<_> = (0..8)
        .map(|_| {
            let (addr, queries, stop) =
                (addr.clone(), Arc::clone(&queries), Arc::clone(&stop));
            let (seen_bmu, seen_project) =
                (Arc::clone(&seen_bmu), Arc::clone(&seen_project));
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                while !stop.load(Ordering::SeqCst) {
                    let round: Vec<(usize, u32)> = queries
                        .chunks(DIM)
                        .map(|x| {
                            let (node, d) = c.bmu(x).unwrap();
                            (node, d.to_bits())
                        })
                        .collect();
                    seen_bmu.lock().unwrap().push(round);
                    seen_project
                        .lock()
                        .unwrap()
                        .push(c.project(DIM, &queries).unwrap());
                }
            })
        })
        .collect();

    // Train map B through the job queue (different data and schedule).
    let input = write_train_file(&dir, 22, 120);
    let out = dir.join("jobout");
    let mut c = Client::connect(&addr).unwrap();
    let argv: Vec<String> = [
        "-x", "7", "-y", "7", "-e", "9", "-r", "3.0", "--threads", "2",
        input.to_str().unwrap(),
        out.to_str().unwrap(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let job = c.submit(&argv).unwrap();
    assert_eq!(job, 1);
    c.watch(job).unwrap();
    let ck_b = loop {
        match c.next_event().unwrap() {
            JobEvent::Done { checkpoint } => break PathBuf::from(checkpoint),
            JobEvent::Failed { code, message } => panic!("job failed: {code}: {message}"),
            JobEvent::Epoch { .. } => {}
        }
    };
    // Let the load threads observe the published map for a few rounds.
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);
    for t in load {
        t.join().unwrap();
    }

    // Offline reference for map B.
    let mut off_b = offline(&ck_b);
    let bmus_b = offline_bmus(&off_b, &queries);
    let project_b =
        off_b.project(DataInput::BorrowedF32 { data: &queries, dim: DIM }).unwrap();
    assert_ne!(*bmus_a, bmus_b, "maps too similar for the swap to be observable");

    // Every recorded answer matches map A or map B — bit-exactly.
    for round in seen_bmu.lock().unwrap().iter() {
        for (i, got) in round.iter().enumerate() {
            assert!(
                *got == bmus_a[i] || *got == bmus_b[i],
                "bmu answer matches neither map: query {i}, got {got:?}"
            );
        }
    }
    // Projected batches are atomic: entirely A or entirely B.
    let mut saw_b = false;
    for batch in seen_project.lock().unwrap().iter() {
        assert!(
            *batch == *project_a || *batch == project_b,
            "torn project batch: {batch:?}"
        );
        saw_b |= *batch == project_b;
    }
    assert!(saw_b, "no load thread ever observed the published map");

    // After the swap, answers come from B and status names its checkpoint.
    let (node, d) = c.bmu(&queries[..DIM]).unwrap();
    assert_eq!((node, d.to_bits()), bmus_b[0]);
    let st = c.status().unwrap();
    assert!(st.checkpoint.ends_with("job1.final.somc"), "{}", st.checkpoint);
    assert_eq!(st.epoch, 9);

    daemon.stop().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Graceful shutdown + journal resume
// ---------------------------------------------------------------------

/// Drain mid-job: the watcher gets a typed `job` error, the job
/// re-queues at its last checkpoint, and a fresh daemon on the same
/// state dir finishes it from there (never from epoch 0).
#[test]
fn drain_requeues_and_restart_resumes() {
    let dir = tmpdir("drain");
    let ck = make_checkpoint(&dir, "a", 31, 3);
    let daemon = DaemonHandle::spawn(serve_opts(&dir, Some(&ck))).unwrap();

    let input = write_train_file(&dir, 32, 120);
    let out = dir.join("jobout");
    let mut watcher = Client::connect(daemon.addr()).unwrap();
    let mut killer = Client::connect(daemon.addr()).unwrap();
    // Checkpoint every epoch so the drain point is always resumable.
    let argv: Vec<String> = [
        "-x", "6", "-y", "6", "-e", "800", "-r", "2.5",
        "--checkpoint-every", "1",
        input.to_str().unwrap(),
        out.to_str().unwrap(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let job = watcher.submit(&argv).unwrap();
    watcher.watch(job).unwrap();
    // First epoch completed (epoch stats are 0-based) -> the job is
    // mid-flight; ask for a drain.
    match watcher.next_event().unwrap() {
        JobEvent::Epoch { epoch, .. } => assert_eq!(epoch, 0),
        other => panic!("wanted the first epoch event, got {other:?}"),
    }
    killer.shutdown().unwrap();
    // The watcher stream ends with a typed drain notice (more epoch
    // events may arrive first while the in-flight epoch finishes).
    let drain_err = loop {
        match watcher.next_event() {
            Ok(JobEvent::Epoch { .. }) => {}
            Ok(other) => panic!("job should not finish during drain: {other:?}"),
            Err(e) => break e,
        }
    };
    assert_eq!(drain_err.code(), "job");
    daemon.wait().unwrap();

    // Restart on the same state dir: the journal re-queues the job and
    // the worker resumes it from its newest checkpoint.
    let daemon2 = DaemonHandle::spawn(serve_opts(&dir, Some(&ck))).unwrap();
    let mut c = Client::connect(daemon2.addr()).unwrap();
    c.watch(job).unwrap();
    let mut first_epoch_after_restart = None;
    let final_ck = loop {
        match c.next_event().unwrap() {
            JobEvent::Epoch { epoch, .. } => {
                first_epoch_after_restart.get_or_insert(epoch);
            }
            JobEvent::Done { checkpoint } => break checkpoint,
            JobEvent::Failed { code, message } => panic!("resume failed: {code}: {message}"),
        }
    };
    // A fresh (non-resumed) run would start back at epoch 0; the drain
    // checkpointed at least one epoch, so a resume starts at >= 1.
    assert!(
        first_epoch_after_restart.unwrap_or(u64::MAX) >= 1,
        "restart must resume from a checkpoint, not epoch 0; \
         got {first_epoch_after_restart:?}"
    );
    assert!(final_ck.ends_with("job1.final.somc"), "{final_ck}");
    let st = c.status().unwrap();
    assert_eq!(st.epoch, 800);
    assert!(st.checkpoint.ends_with("job1.final.somc"), "{}", st.checkpoint);
    // The served map answers match an offline resume of the same final
    // checkpoint — the bit-equality contract survives drain + resume.
    let queries = blob_data(96, 4);
    let want = offline_bmus(&offline(Path::new(&final_ck)), &queries);
    for (x, w) in queries.chunks(DIM).zip(want.iter()) {
        let (node, d) = c.bmu(x).unwrap();
        assert_eq!((node, d.to_bits()), *w);
    }

    daemon2.stop().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
