//! Integration: the simulated-MPI distributed path (paper §3.2, Fig. 8).

use somoclu::cluster::netmodel::NetModel;
use somoclu::cluster::runner::{ClusterData, ClusterReport};
use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::session::Som;
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

/// Single-process training through the session API.
fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> TrainResult {
    Som::builder()
        .config(cfg.clone())
        .build()
        .unwrap()
        .fit_shard(shard)
        .unwrap()
}

/// Cluster training through the session API.
fn fit_cluster(
    cfg: &TrainConfig,
    data: ClusterData,
    net: NetModel,
) -> (TrainResult, ClusterReport) {
    Som::builder()
        .config(cfg.clone())
        .net(net)
        .build()
        .unwrap()
        .fit_cluster(data)
        .unwrap()
}

fn cfg(ranks: usize, epochs: usize) -> TrainConfig {
    TrainConfig {
        rows: 8,
        cols: 8,
        epochs,
        threads: 1,
        ranks,
        radius0: Some(4.0),
        ..Default::default()
    }
}

#[test]
fn rank_count_does_not_change_the_map() {
    let mut rng = Rng::new(200);
    let (d, _) = data::gaussian_blobs(192, 6, 4, 0.2, &mut rng);
    let single = fit(&cfg(1, 6), DataShard::Dense { data: &d, dim: 6 });
    for ranks in [2, 4, 6] {
        let (multi, _) = fit_cluster(
            &cfg(ranks, 6),
            ClusterData::Dense {
                data: d.clone(),
                dim: 6,
            },
            NetModel::ideal(),
        );
        assert_eq!(multi.bmus, single.bmus, "ranks={ranks}");
        // f32 reduction order differs between serial and reduced sums;
        // drift compounds over epochs but stays tiny.
        assert!(
            (multi.final_qe() - single.final_qe()).abs() / single.final_qe() < 1e-4,
            "ranks={ranks}: {} vs {}",
            multi.final_qe(),
            single.final_qe()
        );
    }
}

#[test]
fn uneven_shards_handled() {
    // 101 rows across 4 ranks: shards 26/25/25/25.
    let mut rng = Rng::new(201);
    let (d, _) = data::gaussian_blobs(101, 4, 3, 0.2, &mut rng);
    let (res, _) = fit_cluster(
        &cfg(4, 4),
        ClusterData::Dense { data: d, dim: 4 },
        NetModel::ideal(),
    );
    assert_eq!(res.bmus.len(), 101);
}

#[test]
fn network_model_slows_but_does_not_change_results() {
    let mut rng = Rng::new(202);
    let (d, _) = data::gaussian_blobs(64, 4, 2, 0.2, &mut rng);
    let (ideal, _) = fit_cluster(
        &cfg(2, 3),
        ClusterData::Dense {
            data: d.clone(),
            dim: 4,
        },
        NetModel::ideal(),
    );
    let slow_net = NetModel {
        latency: std::time::Duration::from_micros(200),
        bandwidth: 5e8,
    };
    let (modeled, report) = fit_cluster(
        &cfg(2, 3),
        ClusterData::Dense { data: d, dim: 4 },
        slow_net,
    );
    assert_eq!(ideal.bmus, modeled.bmus);
    assert_eq!(ideal.codebook.weights, modeled.codebook.weights);
    assert!(report.bytes_sent > 0);
}

#[test]
fn sparse_cluster_end_to_end() {
    let mut rng = Rng::new(203);
    let m = Csr::random(120, 64, 0.08, &mut rng);
    let mut c = cfg(3, 5);
    c.kernel = KernelType::SparseCpu;
    let (res, report) = fit_cluster(&c, ClusterData::Sparse(m), NetModel::ideal());
    assert_eq!(res.bmus.len(), 120);
    assert!(res.final_qe().is_finite());
    // Comm volume per epoch: 2 slaves send (N*D + N + 8B qe) and receive
    // N*D codebook + qe total. Sanity-check the order of magnitude.
    let n = 64usize;
    let dim = 64usize;
    let per_slave_per_epoch = (n * dim + n + n * dim) * 4 + 16;
    let expect = 2 * 5 * per_slave_per_epoch as u64;
    assert!(
        report.bytes_sent > expect / 2 && report.bytes_sent < expect * 2,
        "bytes {} vs expected ~{expect}",
        report.bytes_sent
    );
}

#[test]
fn qe_improves_under_distribution_too() {
    let mut rng = Rng::new(204);
    let (d, _) = data::gaussian_blobs(200, 8, 5, 0.15, &mut rng);
    let (res, _) = fit_cluster(
        &cfg(4, 8),
        ClusterData::Dense { data: d, dim: 8 },
        NetModel::ideal(),
    );
    assert!(res.epochs.last().unwrap().qe < res.epochs[0].qe * 0.5);
}
