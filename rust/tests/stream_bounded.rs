//! The out-of-core acceptance property, isolated in its own test binary:
//! the data-buffer gauge (util::memtrack) is process-global, so this
//! measurement must not share a process with other tests that create
//! data sources concurrently.
//!
//! With a fixed `--chunk-rows`, the peak data-buffer allocation is
//! O(chunk_rows * dim) — growing the input 4x must not grow the buffer.
//! (The 100k-row sweep of the same property runs in
//! `benches/stream_memory.rs`; this is the CI-sized proof.)

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::train_stream;
use somoclu::data;
use somoclu::io::dense;
use somoclu::io::stream::ChunkedDenseFileSource;
use somoclu::util::memtrack;
use somoclu::util::rng::Rng;

#[test]
fn data_buffer_stays_bounded_as_rows_grow() {
    let dir = std::env::temp_dir()
        .join(format!("somoclu_stream_bounded_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let dim = 16;
    let chunk_rows = 64;
    let window_bytes = chunk_rows * dim * 4;
    let mut peaks = Vec::new();
    for &rows in &[2000usize, 8000] {
        let mut rng = Rng::new(rows as u64);
        let data = data::random_dense(rows, dim, &mut rng);
        let path = dir.join(format!("data_{rows}.txt"));
        dense::write_dense(&path, rows, dim, &data, false).unwrap();
        drop(data);

        let cfg = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 2,
            threads: 2,
            radius0: Some(3.0),
            ..Default::default()
        };
        memtrack::reset_data_buffer_peak();
        let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
        let res = train_stream(&cfg, &mut src, None, None).unwrap();
        assert_eq!(res.bmus.len(), rows);
        peaks.push(memtrack::data_buffer_peak());
    }
    // Bounded by the window (Vec growth allows a small constant factor),
    // and in particular far below the full 8000-row matrix.
    for (i, &p) in peaks.iter().enumerate() {
        assert!(p >= window_bytes, "peak[{i}] = {p} below one window");
        assert!(
            p <= 4 * window_bytes,
            "peak[{i}] = {p} not O(chunk_rows * dim) (window {window_bytes})"
        );
    }
    assert!(
        peaks[1] <= peaks[0].max(4 * window_bytes),
        "peak grew with rows: {peaks:?}"
    );
}
