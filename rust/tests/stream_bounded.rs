//! The out-of-core acceptance properties, isolated in their own test
//! binary: the data-buffer gauge (util::memtrack) is process-global, so
//! these measurements must not share a process with other tests that
//! create data sources concurrently. One `#[test]` only — the sections
//! run sequentially inside it for the same reason.
//!
//! * With a fixed `--chunk-rows`, the peak data-buffer allocation is
//!   O(chunk_rows * dim) — growing the input 4x must not grow the
//!   buffer. (The 100k-row sweep of the same property runs in
//!   `benches/stream_memory.rs`; this is the CI-sized proof.)
//! * With `--prefetch`, the bound doubles — two transit buffers — and
//!   no more: binary + prefetch stays ≤ 2 × chunk_rows × dim (plus Vec
//!   growth slack), the ISSUE 2 acceptance bound.

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::io::stream::DataSource;
use somoclu::session::Som;
use somoclu::io::binary::{convert_dense_to_binary, BinaryDenseFileSource, SharedFd};
use somoclu::io::dense;
use somoclu::io::stream::{ChunkedDenseFileSource, PrefetchSource};
use somoclu::io::MmapDenseSource;
use somoclu::util::memtrack;
use somoclu::util::rng::Rng;

/// Out-of-core training through the session API.
fn fit_source(
    cfg: &TrainConfig,
    source: &mut dyn DataSource,
) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_source(source)
}

#[test]
fn data_buffer_stays_bounded_as_rows_grow() {
    let dir = std::env::temp_dir()
        .join(format!("somoclu_stream_bounded_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let dim = 16;
    let chunk_rows = 64;
    let window_bytes = chunk_rows * dim * 4;
    let cfg = TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 2,
        threads: 2,
        radius0: Some(3.0),
        ..Default::default()
    };

    // --- Section 1: text streaming, growing input, flat buffer. ---
    let mut peaks = Vec::new();
    let mut big_path = None;
    for &rows in &[2000usize, 8000] {
        let mut rng = Rng::new(rows as u64);
        let data = data::random_dense(rows, dim, &mut rng);
        let path = dir.join(format!("data_{rows}.txt"));
        dense::write_dense(&path, rows, dim, &data, false).unwrap();
        drop(data);

        memtrack::reset_data_buffer_peak();
        let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
        let res = fit_source(&cfg, &mut src).unwrap();
        assert_eq!(res.bmus.len(), rows);
        peaks.push(memtrack::data_buffer_peak());
        big_path = Some(path);
    }
    // Bounded by the window (Vec growth allows a small constant factor),
    // and in particular far below the full 8000-row matrix.
    for (i, &p) in peaks.iter().enumerate() {
        assert!(p >= window_bytes, "peak[{i}] = {p} below one window");
        assert!(
            p <= 4 * window_bytes,
            "peak[{i}] = {p} not O(chunk_rows * dim) (window {window_bytes})"
        );
    }
    assert!(
        peaks[1] <= peaks[0].max(4 * window_bytes),
        "peak grew with rows: {peaks:?}"
    );

    // --- Section 2: binary + prefetch holds ≤ 2 windows. ---
    // The binary source reads exactly chunk_rows * dim floats per chunk
    // (no parse-time Vec growth), so the prefetched pair of transit
    // buffers is exactly 2 windows; allow slack for the final short
    // chunk bookkeeping and the sparse indptr decode buffer (absent
    // here), but the bound must stay strictly under 3 windows — i.e.
    // two buffers, not three (no hidden staging copy).
    let big_path = big_path.unwrap();
    let bin_path = dir.join("data_big.somb");
    {
        let mut src = ChunkedDenseFileSource::open(&big_path, 1024).unwrap();
        convert_dense_to_binary(&mut src, &bin_path).unwrap();
    }
    memtrack::reset_data_buffer_peak();
    {
        let inner = BinaryDenseFileSource::open(&bin_path, chunk_rows).unwrap();
        let mut src = PrefetchSource::new(inner);
        let res = fit_source(&cfg, &mut src).unwrap();
        assert_eq!(res.bmus.len(), 8000);
    }
    let peak = memtrack::data_buffer_peak();
    assert!(
        peak >= window_bytes,
        "prefetch peak {peak} below one window {window_bytes}"
    );
    assert!(
        peak <= 2 * window_bytes + window_bytes / 2,
        "prefetch peak {peak} exceeds the 2-window bound (window {window_bytes})"
    );

    // --- Section 3: plain binary streaming holds one window. ---
    memtrack::reset_data_buffer_peak();
    {
        let mut src = BinaryDenseFileSource::open(&bin_path, chunk_rows).unwrap();
        let res = fit_source(&cfg, &mut src).unwrap();
        assert_eq!(res.bmus.len(), 8000);
    }
    let peak = memtrack::data_buffer_peak();
    assert!(
        peak <= window_bytes + window_bytes / 2,
        "binary streaming peak {peak} exceeds one window {window_bytes}"
    );

    // --- Section 4: pread (shared fd) has the same one-window bound. ---
    memtrack::reset_data_buffer_peak();
    {
        let mut src = SharedFd::open(&bin_path)
            .unwrap()
            .dense_shard(chunk_rows, 0, 1)
            .unwrap();
        let res = fit_source(&cfg, &mut src).unwrap();
        assert_eq!(res.bmus.len(), 8000);
    }
    let peak = memtrack::data_buffer_peak();
    assert!(
        peak <= window_bytes + window_bytes / 2,
        "pread streaming peak {peak} exceeds one window {window_bytes}"
    );

    // --- Section 5: mmap owns ~no heap; its mapped-window gauge is ---
    // --- bounded by one window. ------------------------------------
    if somoclu::io::mmap::SUPPORTED {
        memtrack::reset_data_buffer_peak();
        memtrack::reset_data_map_peak();
        let heap_live_before = memtrack::data_buffer_bytes();
        {
            let mut src = MmapDenseSource::open(&bin_path, chunk_rows).unwrap();
            let res = fit_source(&cfg, &mut src).unwrap();
            assert_eq!(res.bmus.len(), 8000);
        }
        // Zero-copy: the dense mmap source allocates no chunk buffers at
        // all, so the heap gauge must not have moved beyond the live
        // baseline (earlier sections' sources are all dropped).
        let heap_peak = memtrack::data_buffer_peak();
        assert!(
            heap_peak <= heap_live_before + 4 * 1024,
            "mmap dense source allocated data buffers: peak {heap_peak}, \
             baseline {heap_live_before}"
        );
        // The mapped-window gauge replaces the heap gauge as the bound
        // carrier: exactly one exposed chunk view at a time.
        let map_peak = memtrack::data_map_peak();
        assert!(
            map_peak >= window_bytes,
            "mmap map-gauge peak {map_peak} below one window {window_bytes}"
        );
        assert!(
            map_peak <= window_bytes + window_bytes / 2,
            "mmap map-gauge peak {map_peak} exceeds one window {window_bytes}"
        );
        // And it releases on drop.
        assert_eq!(memtrack::data_map_bytes(), 0, "mapped view bytes leaked");
    } else {
        eprintln!("skipping mmap gauge section (no mmap backend in this build)");
    }
}
