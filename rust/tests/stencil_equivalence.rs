//! ISSUE 5 acceptance: the stencil/windowed accumulation path produces
//! **bit-identical** results to the pre-refactor full sweep — same
//! `num`, `den`, `bmus`, `qe_sum` bits — across every grid/map
//! combination, every neighborhood, a radius sweep from sub-cell
//! windows to map-covering cutoffs, and every thread count.
//!
//! Three layers of evidence:
//!  * `oracle_old_path` reimplements the PRE-refactor accumulator
//!    verbatim (scan-filter Phase A + dense-sweep Phase B) and is
//!    compared against both [`SweepMode::FullSweep`] (pins the Phase A
//!    counting-sort refactor) and [`SweepMode::Auto`] (pins the whole
//!    stencil path).
//!  * Kernel-level sweeps drive `DenseCpuKernel`/`SparseCpuKernel`
//!    end-to-end and compare their accumulators against the forced full
//!    sweep fed the same BMUs.
//!  * Targeted shapes: r < 1 single-cell windows, toroid windows that
//!    wrap both axes, tall/narrow maps where one axis degrades to Full,
//!    the non-compact gaussian whose 7.5·r cutoff forces the dense
//!    fast path, and thread-count invariance of the bucketed Phase A.

use somoclu::kernels::dense_cpu::{accumulate_node_parallel_ext, DenseCpuKernel};
use somoclu::kernels::sparse_cpu::SparseCpuKernel;
use somoclu::kernels::{AccumConfig, DataShard, SweepMode, TrainingKernel};
use somoclu::som::grid::{GridType, MapType};
use somoclu::som::{Codebook, Grid, Neighborhood, NeighborhoodStencil};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{what} length ({ctx})");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}] {x:?} != {y:?} ({ctx})"
        );
    }
}

/// The accumulator exactly as it existed before this refactor:
/// sequential scan-filter Phase A (row order per node), then the dense
/// Phase B sweep over active BMUs in ascending order. Single-threaded —
/// the node-parallel split never changed per-node arithmetic order.
#[allow(clippy::too_many_arguments)]
fn oracle_old_path(
    rows: usize,
    nodes: usize,
    dim: usize,
    grid: &Grid,
    nb: Neighborhood,
    radius: f32,
    scale: f32,
    bmus: &[u32],
    data: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let cutoff = nb.cutoff(radius);
    let mut xsum = vec![0.0f32; nodes * dim];
    let mut cnt = vec![0.0f32; nodes];
    for (r, &b) in bmus[..rows].iter().enumerate() {
        let b = b as usize;
        let x = &data[r * dim..(r + 1) * dim];
        for (acc, v) in xsum[b * dim..(b + 1) * dim].iter_mut().zip(x) {
            // == h * v with the h = 1.0 the old Phase A passed (1.0 * x
            // is bitwise x).
            *acc += v;
        }
        cnt[b] += 1.0;
    }
    let active: Vec<u32> = (0..nodes as u32)
        .filter(|&b| cnt[b as usize] > 0.0)
        .collect();
    let mut num = vec![0.0f32; nodes * dim];
    let mut den = vec![0.0f32; nodes];
    for node in 0..nodes {
        let mut d_acc = 0.0f32;
        let num_row = &mut num[node * dim..(node + 1) * dim];
        for &b in &active {
            let gd = grid.distance(b as usize, node);
            if gd > cutoff {
                continue;
            }
            let h = nb.weight(gd, radius) * scale;
            if h <= 0.0 {
                continue;
            }
            d_acc += h * cnt[b as usize];
            let src = &xsum[b as usize * dim..(b as usize + 1) * dim];
            for (a, s) in num_row.iter_mut().zip(src) {
                *a = s.mul_add(h, *a);
            }
        }
        den[node] = d_acc;
    }
    (num, den)
}

#[allow(clippy::too_many_arguments)]
fn run_ext(
    grid: &Grid,
    nb: Neighborhood,
    radius: f32,
    scale: f32,
    threads: usize,
    mode: SweepMode,
    bmus: &[u32],
    data: &[f32],
    dim: usize,
) -> (Vec<f32>, Vec<f32>, somoclu::kernels::AccumStats) {
    accumulate_node_parallel_ext(
        &AccumConfig {
            rows: bmus.len(),
            nodes: grid.node_count(),
            dim,
            threads,
            grid,
            neighborhood: nb,
            radius,
            scale,
            mode,
        },
        bmus,
        |num_row, r, h| {
            let x = &data[r * dim..(r + 1) * dim];
            for (acc, v) in num_row.iter_mut().zip(x) {
                *acc += h * v;
            }
        },
    )
}

fn all_grids(rows: usize, cols: usize) -> Vec<Grid> {
    let mut v = Vec::new();
    for gt in [GridType::Square, GridType::Hexagonal] {
        for mt in [MapType::Planar, MapType::Toroid] {
            v.push(Grid::new(rows, cols, gt, mt));
        }
    }
    v
}

fn neighborhoods() -> [Neighborhood; 3] {
    [
        Neighborhood::gaussian(false),
        Neighborhood::gaussian(true),
        Neighborhood::bubble(),
    ]
}

/// The headline property: radius sweep over every grid/map/neighborhood
/// combo, Auto and FullSweep and the pre-refactor oracle all agree bit
/// for bit, and the sweep actually exercises BOTH Phase B strategies.
#[test]
fn radius_sweep_bit_identical_all_combos() {
    let mut rng = Rng::new(0x57E2C11);
    let (mut stencil_runs, mut dense_runs) = (0usize, 0usize);
    for grid in all_grids(9, 11) {
        let nodes = grid.node_count();
        let dim = 5;
        let rows = 64;
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let bmus: Vec<u32> = (0..rows).map(|_| rng.below(nodes as u64) as u32).collect();
        for nb in neighborhoods() {
            for radius in [0.3f32, 0.9, 1.4, 2.0, 3.1, 4.5, 7.0, 12.0] {
                let scale = 0.77f32;
                let ctx = format!(
                    "{:?}/{:?} {nb:?} r={radius}",
                    grid.grid_type, grid.map_type
                );
                let (o_num, o_den) = oracle_old_path(
                    rows, nodes, dim, &grid, nb, radius, scale, &bmus, &data,
                );
                let (f_num, f_den, f_stats) = run_ext(
                    &grid, nb, radius, scale, 3, SweepMode::FullSweep, &bmus, &data, dim,
                );
                let (a_num, a_den, a_stats) = run_ext(
                    &grid, nb, radius, scale, 3, SweepMode::Auto, &bmus, &data, dim,
                );
                assert!(!f_stats.stencil);
                if a_stats.stencil {
                    stencil_runs += 1;
                } else {
                    dense_runs += 1;
                }
                assert_bits_eq(&f_num, &o_num, "full-sweep num vs oracle", &ctx);
                assert_bits_eq(&f_den, &o_den, "full-sweep den vs oracle", &ctx);
                assert_bits_eq(&a_num, &o_num, "auto num vs oracle", &ctx);
                assert_bits_eq(&a_den, &o_den, "auto den vs oracle", &ctx);
            }
        }
    }
    assert!(stencil_runs > 25, "stencil path underexercised: {stencil_runs}");
    assert!(dense_runs > 40, "dense path underexercised: {dense_runs}");
}

/// Dense kernel end-to-end: whole `EpochAccum` (bmus, num, den, qe_sum)
/// bit-identical between the kernel's Auto path and the forced full
/// sweep fed the same BMUs.
#[test]
fn dense_kernel_accum_bit_identical_at_stencil_radii() {
    let mut rng = Rng::new(0xD15E);
    for grid in all_grids(12, 10) {
        let dim = 7;
        let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
        let data: Vec<f32> = (0..90 * dim).map(|_| rng.normal_f32()).collect();
        for nb in neighborhoods() {
            for radius in [0.6f32, 1.5, 2.5] {
                let mut k = DenseCpuKernel::new(4);
                let got = k
                    .epoch_accumulate(
                        DataShard::Dense { data: &data, dim },
                        &cb,
                        &grid,
                        nb,
                        radius,
                        0.9,
                    )
                    .unwrap();
                let (w_num, w_den, _) = run_ext(
                    &grid, nb, radius, 0.9, 4, SweepMode::FullSweep, &got.bmus, &data, dim,
                );
                let ctx = format!("{:?}/{:?} {nb:?} r={radius}", grid.grid_type, grid.map_type);
                assert_bits_eq(&got.num, &w_num, "kernel num vs full sweep", &ctx);
                assert_bits_eq(&got.den, &w_den, "kernel den vs full sweep", &ctx);
            }
        }
    }
}

/// Sparse kernel end-to-end with the sparse axpy closure.
#[test]
fn sparse_kernel_accum_bit_identical_at_stencil_radii() {
    let mut rng = Rng::new(0x5A50);
    for grid in all_grids(11, 9) {
        let dim = 20;
        let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
        let m = Csr::random(70, dim, 0.25, &mut rng);
        for nb in neighborhoods() {
            for radius in [0.6f32, 1.6, 2.4] {
                let mut k = SparseCpuKernel::new(3);
                let got = k
                    .epoch_accumulate(DataShard::Sparse(m.view()), &cb, &grid, nb, radius, 1.0)
                    .unwrap();
                let (w_num, w_den, _) = accumulate_node_parallel_ext(
                    &AccumConfig {
                        rows: m.rows,
                        nodes: grid.node_count(),
                        dim,
                        threads: 3,
                        grid: &grid,
                        neighborhood: nb,
                        radius,
                        scale: 1.0,
                        mode: SweepMode::FullSweep,
                    },
                    &got.bmus,
                    |num_row, r, h| {
                        let (cols, vals) = m.row(r);
                        for (c, v) in cols.iter().zip(vals) {
                            num_row[*c as usize] += h * v;
                        }
                    },
                );
                let ctx = format!("{:?}/{:?} {nb:?} r={radius}", grid.grid_type, grid.map_type);
                assert_bits_eq(&got.num, &w_num, "sparse num vs full sweep", &ctx);
                assert_bits_eq(&got.den, &w_den, "sparse den vs full sweep", &ctx);
            }
        }
    }
}

/// r < 1: the window collapses to (nearly) a single cell and must still
/// match — including the BMU's own full weight.
#[test]
fn sub_cell_radius_single_cell_window() {
    let mut rng = Rng::new(0x5B);
    for grid in all_grids(16, 16) {
        let dim = 3;
        let rows = 48;
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let bmus: Vec<u32> =
            (0..rows).map(|_| rng.below(grid.node_count() as u64) as u32).collect();
        for nb in neighborhoods() {
            for radius in [0.05f32, 0.4, 0.99] {
                let (o_num, o_den) = oracle_old_path(
                    rows, grid.node_count(), dim, &grid, nb, radius, 1.0, &bmus, &data,
                );
                let (a_num, a_den, st) =
                    run_ext(&grid, nb, radius, 1.0, 2, SweepMode::Auto, &bmus, &data, dim);
                if nb.compact_support {
                    // (Non-compact gaussians carry a 7.5·r cutoff, so
                    // their windows are legitimately wider or dense.)
                    assert!(st.stencil, "r={radius} should window on a 16x16 map");
                    assert!(st.window_cells <= 35, "r<1 window stays tiny");
                }
                let ctx = format!("{:?}/{:?} r={radius}", grid.grid_type, grid.map_type);
                assert_bits_eq(&a_num, &o_num, "num", &ctx);
                assert_bits_eq(&a_den, &o_den, "den", &ctx);
            }
        }
    }
}

/// Toroid maps small enough that every node's window wraps both axes,
/// plus tall/narrow maps where one axis degrades to Full coverage.
#[test]
fn toroid_wrapping_and_full_axis_windows() {
    let mut rng = Rng::new(0x7012);
    let shapes = [(9usize, 9usize, 1.5f32), (3, 17, 2.0), (17, 3, 2.0), (5, 24, 1.8)];
    for (rows_g, cols_g, radius) in shapes {
        for gt in [GridType::Square, GridType::Hexagonal] {
            let grid = Grid::new(rows_g, cols_g, gt, MapType::Toroid);
            let dim = 4;
            let rows = 80;
            let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
            let bmus: Vec<u32> =
                (0..rows).map(|_| rng.below(grid.node_count() as u64) as u32).collect();
            for nb in [Neighborhood::gaussian(true), Neighborhood::bubble()] {
                let (o_num, o_den) = oracle_old_path(
                    rows, grid.node_count(), dim, &grid, nb, radius, 0.66, &bmus, &data,
                );
                let (a_num, a_den, st) =
                    run_ext(&grid, nb, radius, 0.66, 4, SweepMode::Auto, &bmus, &data, dim);
                let ctx = format!("{rows_g}x{cols_g} {gt:?} {nb:?} r={radius}");
                assert!(st.stencil, "these shapes must take the stencil path ({ctx})");
                assert!(
                    st.window_cells < grid.node_count(),
                    "window must undercut lattice ({ctx})"
                );
                assert_bits_eq(&a_num, &o_num, "num", &ctx);
                assert_bits_eq(&a_den, &o_den, "den", &ctx);
            }
        }
    }
}

/// Non-compact gaussian: cutoff 7.5·r beyond the map span ⇒ the stencil
/// declines (dense fast path) and nothing changes.
#[test]
fn non_compact_gaussian_takes_dense_fast_path() {
    let mut rng = Rng::new(0xFA57);
    let grid = Grid::new(10, 10, GridType::Hexagonal, MapType::Planar);
    let nb = Neighborhood::gaussian(false);
    let radius = 3.0; // cutoff 22.5 > span
    assert!(NeighborhoodStencil::build(&grid, nb, radius, 1.0).is_none());
    let dim = 3;
    let rows = 40;
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bmus: Vec<u32> =
        (0..rows).map(|_| rng.below(grid.node_count() as u64) as u32).collect();
    for mode in [SweepMode::Auto, SweepMode::FullSweep] {
        let (num, den, st) = run_ext(&grid, nb, radius, 1.0, 2, mode, &bmus, &data, dim);
        assert!(!st.stencil, "{mode:?} must fall back to the dense sweep");
        assert_eq!(st.window_cells, 0);
        let (o_num, o_den) =
            oracle_old_path(rows, grid.node_count(), dim, &grid, nb, radius, 1.0, &bmus, &data);
        assert_bits_eq(&num, &o_num, "num", &format!("{mode:?}"));
        assert_bits_eq(&den, &o_den, "den", &format!("{mode:?}"));
    }
}

/// The bucketed Phase A and the windowed Phase B are both node-owned:
/// thread count must never change a single output bit.
#[test]
fn thread_count_invariance_bucketed_and_stencil() {
    let mut rng = Rng::new(0x7C0);
    for grid in all_grids(13, 8) {
        let dim = 6;
        let rows = 257; // odd, not a multiple of any thread count
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let bmus: Vec<u32> =
            (0..rows).map(|_| rng.below(grid.node_count() as u64) as u32).collect();
        for radius in [1.2f32, 6.0] {
            let nb = Neighborhood::gaussian(true);
            let (n1, d1, _) =
                run_ext(&grid, nb, radius, 0.5, 1, SweepMode::Auto, &bmus, &data, dim);
            for threads in [2usize, 3, 8, 16] {
                let (nt, dt, _) = run_ext(
                    &grid, nb, radius, 0.5, threads, SweepMode::Auto, &bmus, &data, dim,
                );
                let ctx = format!(
                    "{:?}/{:?} r={radius} threads={threads}",
                    grid.grid_type, grid.map_type
                );
                assert_bits_eq(&nt, &n1, "num", &ctx);
                assert_bits_eq(&dt, &d1, "den", &ctx);
            }
        }
    }
}

/// Lazy per-row hex stencils: radii wide enough that an eager per-row
/// table would blow the `MAX_TABLE_CELLS_PER_NODE` budget now build in
/// lazy mode (instead of declining to the dense sweep) and must stay
/// bit-identical to the pre-refactor oracle. The scan crosses the
/// eager→lazy threshold so both modes are exercised on the same map.
#[test]
fn lazy_hex_stencils_bit_identical_to_oracle() {
    let mut rng = Rng::new(0x1A27);
    let dim = 4;
    let rows = 120;
    let nb = Neighborhood::gaussian(true);
    for mt in [MapType::Planar, MapType::Toroid] {
        let grid = Grid::new(48, 18, GridType::Hexagonal, mt);
        let nodes = grid.node_count();
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let bmus: Vec<u32> = (0..rows).map(|_| rng.below(nodes as u64) as u32).collect();
        let (mut lazy_runs, mut eager_runs) = (0usize, 0usize);
        for radius in [3.0f32, 5.0, 8.0, 10.0, 12.0] {
            let built = NeighborhoodStencil::build(&grid, nb, radius, 0.8);
            match &built {
                Some(st) if st.is_lazy() => lazy_runs += 1,
                Some(_) => eager_runs += 1,
                None => {}
            }
            let (o_num, o_den) =
                oracle_old_path(rows, nodes, dim, &grid, nb, radius, 0.8, &bmus, &data);
            let (a_num, a_den, st) =
                run_ext(&grid, nb, radius, 0.8, 4, SweepMode::Auto, &bmus, &data, dim);
            assert_eq!(
                st.stencil,
                built.is_some(),
                "Auto must window whenever a stencil builds ({mt:?} r={radius})"
            );
            let ctx = format!("{mt:?} r={radius}");
            assert_bits_eq(&a_num, &o_num, "lazy-scan num", &ctx);
            assert_bits_eq(&a_den, &o_den, "lazy-scan den", &ctx);
        }
        assert!(lazy_runs >= 2, "lazy stencil underexercised ({mt:?}): {lazy_runs}");
        assert!(eager_runs >= 1, "eager stencil underexercised ({mt:?}): {eager_runs}");
    }
}

/// Empty shards and single-BMU pileups go through both paths unharmed.
#[test]
fn degenerate_shards() {
    let grid = Grid::new(12, 12, GridType::Square, MapType::Toroid);
    let nb = Neighborhood::gaussian(true);
    let dim = 2;
    // No rows at all.
    let (num, den, _) = run_ext(&grid, nb, 2.0, 1.0, 4, SweepMode::Auto, &[], &[], dim);
    assert!(num.iter().all(|&v| v == 0.0) && den.iter().all(|&v| v == 0.0));
    // Every row lands on one BMU.
    let rows = 100;
    let data = vec![1.0f32; rows * dim];
    let bmus = vec![77u32; rows];
    let (o_num, o_den) =
        oracle_old_path(rows, grid.node_count(), dim, &grid, nb, 2.0, 1.0, &bmus, &data);
    let (a_num, a_den, st) =
        run_ext(&grid, nb, 2.0, 1.0, 4, SweepMode::Auto, &bmus, &data, dim);
    assert!(st.stencil);
    assert_eq!(st.active_bmus, 1);
    assert_bits_eq(&a_num, &o_num, "num", "single-bmu");
    assert_bits_eq(&a_den, &o_den, "den", "single-bmu");
}
