//! The session-API acceptance suite (ISSUE 4):
//!
//! * **Resume equivalence, exactly**: a run checkpointed at *any* epoch
//!   and resumed produces bit-identical codebook weights and BMUs to
//!   the same run uninterrupted — dense + sparse, resident + streamed
//!   (`--chunk-rows`), single-process + cluster windows.
//! * **SOMC rejection**: truncated / bit-rotted / version-mismatched
//!   checkpoints fail `Som::resume` with a clear error (format-level
//!   unit tests live in `io::checkpoint`; this covers the public path).
//! * **Kernel cache regression**: consecutive `step_epoch` calls on one
//!   session hit the kernel's `epoch_begin` cache on every chunk — zero
//!   misses — the fix for the legacy `train_one_epoch`
//!   kernel-rebuild-per-call behavior.
//! * Inference (`bmu`/`project`) serves a trained or resumed map.

use somoclu::api::DataInput;
use somoclu::cluster::runner::ClusterData;
use somoclu::coordinator::config::TrainConfig;
use somoclu::io::stream::ChunkedDenseFileSource;
use somoclu::io::{dense, sparse as sparse_io};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::session::{checkpoint_path, Som, SomSession};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("somoclu_session_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_cfg(kernel: KernelType, epochs: usize, chunk_rows: usize) -> TrainConfig {
    TrainConfig {
        rows: 6,
        cols: 6,
        epochs,
        kernel,
        threads: 2,
        chunk_rows,
        radius0: Some(3.0),
        ..Default::default()
    }
}

fn session(cfg: &TrainConfig) -> SomSession {
    Som::builder().config(cfg.clone()).build().unwrap()
}

/// Bit-level equality of two weight buffers.
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ab, bb, "{what}: codebook bits diverged");
}

/// Resume-equivalence property on resident data: for every save epoch
/// `k`, interrupt-at-`k` + resume must land on the uninterrupted run's
/// exact bits.
fn check_resident_resume(cfg: &TrainConfig, shard: DataShard<'_>, dir: &std::path::Path) {
    let full = session(cfg).fit_shard(shard).unwrap();
    for k in 1..cfg.epochs {
        // Phase 1: train k epochs, checkpoint, drop everything.
        let ckpt = dir.join(format!("resident_k{k}.somc"));
        {
            let mut s = session(cfg);
            for _ in 0..k {
                s.step_epoch_shard(shard).unwrap();
            }
            s.save_checkpoint(&ckpt).unwrap();
        }
        // Phase 2: a fresh process-equivalent resumes and finishes.
        // Runtime knobs are not stored in checkpoints; restore the same
        // chunking (bit-exactness requires identical f32 sum order).
        let mut resumed = Som::resume(&ckpt).unwrap();
        resumed.set_chunk_rows(cfg.chunk_rows);
        resumed.set_threads(cfg.threads);
        assert_eq!(resumed.epoch(), k);
        let res = resumed.fit_shard(shard).unwrap();
        assert_eq!(res.bmus, full.bmus, "k={k}: BMUs diverged");
        assert_bits_eq(
            &res.codebook.weights,
            &full.codebook.weights,
            &format!("k={k}"),
        );
        assert_eq!(res.epochs.len(), cfg.epochs - k, "k={k}: epoch stats");
    }
}

/// `step_epoch` needs a `DataShard` entry point for the property loops.
trait StepShard {
    fn step_epoch_shard(&mut self, shard: DataShard<'_>) -> anyhow::Result<()>;
}

impl StepShard for SomSession {
    fn step_epoch_shard(&mut self, shard: DataShard<'_>) -> anyhow::Result<()> {
        match shard {
            DataShard::Dense { data, dim } => {
                self.step_epoch(DataInput::BorrowedF32 { data, dim })?;
            }
            DataShard::Sparse(_) => {
                let mut src = somoclu::io::InMemorySource::new(shard, self.config().chunk_rows);
                self.step_epoch_source(&mut src)?;
            }
        }
        Ok(())
    }
}

#[test]
fn resume_equivalence_dense_resident() {
    let dir = tmpdir("dense_res");
    let mut rng = Rng::new(900);
    let (data, _) = somoclu::data::gaussian_blobs(60, 5, 3, 0.2, &mut rng);
    let shard = DataShard::Dense { data: &data, dim: 5 };
    // Whole-pass and chunked variants (same chunking on both sides —
    // the documented requirement for bit-exactness).
    for chunk_rows in [0usize, 7] {
        let cfg = small_cfg(KernelType::DenseCpu, 5, chunk_rows);
        check_resident_resume(&cfg, shard, &dir);
    }
}

#[test]
fn resume_equivalence_sparse_resident() {
    let dir = tmpdir("sparse_res");
    let mut rng = Rng::new(901);
    let m = Csr::random(50, 18, 0.25, &mut rng);
    for chunk_rows in [0usize, 9] {
        let cfg = small_cfg(KernelType::SparseCpu, 4, chunk_rows);
        check_resident_resume(&cfg, DataShard::Sparse(m.view()), &dir);
    }
}

#[test]
fn resume_equivalence_streamed_dense_file() {
    // The --chunk-rows streamed fit of the acceptance criterion: train
    // over a file-backed source, checkpoint mid-schedule, resume with a
    // freshly opened source (a new process would), finish — bit-equal.
    let dir = tmpdir("dense_stream");
    let mut rng = Rng::new(902);
    let (data, _) = somoclu::data::gaussian_blobs(90, 5, 3, 0.2, &mut rng);
    let path = dir.join("data.txt");
    dense::write_dense(&path, 90, 5, &data, false).unwrap();
    let cfg = small_cfg(KernelType::DenseCpu, 6, 8);

    let full = {
        let mut src = ChunkedDenseFileSource::open(&path, cfg.chunk_rows).unwrap();
        session(&cfg).fit_source(&mut src).unwrap()
    };
    for k in [1usize, 3, 5] {
        let ckpt = dir.join(format!("stream_k{k}.somc"));
        {
            let mut s = session(&cfg);
            let mut src = ChunkedDenseFileSource::open(&path, cfg.chunk_rows).unwrap();
            for _ in 0..k {
                s.step_epoch_source(&mut src).unwrap();
            }
            s.save_checkpoint(&ckpt).unwrap();
        }
        let mut resumed = Som::resume(&ckpt).unwrap();
        resumed.set_chunk_rows(cfg.chunk_rows);
        resumed.set_threads(cfg.threads);
        let mut src = ChunkedDenseFileSource::open(&path, cfg.chunk_rows).unwrap();
        let res = resumed.fit_source(&mut src).unwrap();
        assert_eq!(res.bmus, full.bmus, "k={k}");
        assert_bits_eq(&res.codebook.weights, &full.codebook.weights, &format!("k={k}"));
    }
}

#[test]
fn resume_equivalence_streamed_sparse_file() {
    let dir = tmpdir("sparse_stream");
    let mut rng = Rng::new(903);
    let m = Csr::random(60, 20, 0.2, &mut rng);
    let path = dir.join("data.svm");
    sparse_io::write_sparse(&path, &m).unwrap();
    let cfg = small_cfg(KernelType::SparseCpu, 5, 7);

    let open = || somoclu::io::ChunkedSparseFileSource::open(&path, 20, cfg.chunk_rows).unwrap();
    let full = {
        let mut src = open();
        session(&cfg).fit_source(&mut src).unwrap()
    };
    let k = 2;
    let ckpt = dir.join("sparse_stream.somc");
    {
        let mut s = session(&cfg);
        let mut src = open();
        for _ in 0..k {
            s.step_epoch_source(&mut src).unwrap();
        }
        s.save_checkpoint(&ckpt).unwrap();
    }
    let mut resumed = Som::resume(&ckpt).unwrap();
    resumed.set_chunk_rows(cfg.chunk_rows);
    resumed.set_threads(cfg.threads);
    let mut src = open();
    let res = resumed.fit_source(&mut src).unwrap();
    assert_eq!(res.bmus, full.bmus);
    assert_bits_eq(&res.codebook.weights, &full.codebook.weights, "sparse stream");
}

#[test]
fn checkpoint_every_policy_writes_resumable_files() {
    // The CLI contract in library form: a 6-epoch fit with
    // checkpoint_every(2) leaves epoch2/4/6 files; resuming the
    // mid-schedule one finishes bit-identically.
    let dir = tmpdir("policy");
    let prefix = dir.join("run");
    let mut rng = Rng::new(904);
    let (data, _) = somoclu::data::gaussian_blobs(48, 4, 3, 0.2, &mut rng);
    let cfg = small_cfg(KernelType::DenseCpu, 6, 0);

    let full = Som::builder()
        .config(cfg.clone())
        .checkpoint_every(2, &prefix)
        .build()
        .unwrap()
        .fit(DataInput::BorrowedF32 { data: &data, dim: 4 })
        .unwrap();
    for k in [2usize, 4, 6] {
        assert!(checkpoint_path(&prefix, k).exists(), "missing epoch{k} checkpoint");
    }

    let mut resumed = Som::resume(checkpoint_path(&prefix, 4)).unwrap();
    resumed.set_threads(cfg.threads);
    assert_eq!(resumed.epoch(), 4);
    let res = resumed
        .fit(DataInput::BorrowedF32 { data: &data, dim: 4 })
        .unwrap();
    assert_eq!(res.bmus, full.bmus);
    assert_bits_eq(&res.codebook.weights, &full.codebook.weights, "policy resume");
}

#[test]
fn cluster_resume_mid_schedule_matches_uninterrupted() {
    // Multi-rank resume: a coordinator checkpoint taken between cluster
    // windows seeds every rank mid-schedule; finishing matches the
    // uninterrupted cluster run bit-for-bit (fixed rank count).
    let dir = tmpdir("cluster");
    let prefix = dir.join("cl");
    let mut rng = Rng::new(905);
    let (data, _) = somoclu::data::gaussian_blobs(72, 4, 3, 0.2, &mut rng);
    let mut cfg = small_cfg(KernelType::DenseCpu, 6, 0);
    cfg.ranks = 3;
    let make = || ClusterData::Dense {
        data: data.clone(),
        dim: 4,
    };

    let (full, _) = Som::builder()
        .config(cfg.clone())
        .build()
        .unwrap()
        .fit_cluster(make())
        .unwrap();

    // Interrupted variant: checkpoint every 2 epochs, stop after the
    // epoch-2 window by resuming from its file.
    let (_, _) = Som::builder()
        .config(cfg.clone())
        .checkpoint_every(2, &prefix)
        .build()
        .unwrap()
        .fit_cluster(make())
        .unwrap();
    let mut resumed = Som::resume(checkpoint_path(&prefix, 2)).unwrap();
    resumed.set_ranks(cfg.ranks);
    resumed.set_threads(cfg.threads);
    assert_eq!(resumed.epoch(), 2);
    let (res, _) = resumed.fit_cluster(make()).unwrap();
    assert_eq!(res.bmus, full.bmus);
    assert_bits_eq(&res.codebook.weights, &full.codebook.weights, "cluster resume");
}

#[test]
fn resume_rejects_damaged_checkpoints() {
    let dir = tmpdir("damage");
    let mut rng = Rng::new(906);
    let (data, _) = somoclu::data::gaussian_blobs(30, 4, 2, 0.3, &mut rng);
    let cfg = small_cfg(KernelType::DenseCpu, 3, 0);
    let ckpt = dir.join("ok.somc");
    {
        let mut s = session(&cfg);
        s.step_epoch(DataInput::BorrowedF32 { data: &data, dim: 4 }).unwrap();
        s.save_checkpoint(&ckpt).unwrap();
    }
    let bytes = std::fs::read(&ckpt).unwrap();

    // Truncated payload.
    let p = dir.join("trunc.somc");
    std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
    let err = format!("{:#}", Som::resume(&p).unwrap_err());
    assert!(err.contains("truncated"), "{err}");

    // Version from the future.
    let p = dir.join("vers.somc");
    let mut b = bytes.clone();
    b[4..8].copy_from_slice(&7u32.to_le_bytes());
    std::fs::write(&p, &b).unwrap();
    let err = format!("{:#}", Som::resume(&p).unwrap_err());
    assert!(err.contains("version"), "{err}");

    // One flipped payload bit -> checksum mismatch.
    let p = dir.join("rot.somc");
    let mut b = bytes.clone();
    let off = somoclu::io::checkpoint::HEADER_LEN as usize + 13;
    b[off] ^= 0x40;
    std::fs::write(&p, &b).unwrap();
    let err = format!("{:#}", Som::resume(&p).unwrap_err());
    assert!(err.contains("checksum"), "{err}");

    // Not a checkpoint at all.
    let p = dir.join("noise.somc");
    std::fs::write(&p, b"definitely not a checkpoint").unwrap();
    assert!(Som::resume(&p).is_err());
}

#[test]
fn step_epochs_hit_the_kernel_begin_cache() {
    // THE regression guard for the legacy kernel-rebuild-per-call bug:
    // a session stepping chunked epochs must construct ONE kernel and
    // hit its epoch_begin cache on every chunk — zero misses. The cache
    // key is the codebook-fingerprint path (`codebook_key`), so this
    // also proves the begin/accumulate keying survives in-place updates
    // across steps.
    let mut rng = Rng::new(907);
    let (data, _) = somoclu::data::gaussian_blobs(50, 4, 3, 0.2, &mut rng);
    let cfg = small_cfg(KernelType::DenseCpu, 10, 10); // 5 chunks/epoch
    let mut s = session(&cfg);
    let steps = 3usize;
    for _ in 0..steps {
        s.step_epoch(DataInput::BorrowedF32 { data: &data, dim: 4 }).unwrap();
    }
    let (hits, misses) = s.kernel_cache_stats().expect("cpu kernel tracks stats");
    assert_eq!(misses, 0, "a session step recomputed the epoch_begin cache");
    assert_eq!(hits, (steps * 5) as u64, "every chunk must hit the cache");

    // Sparse kernel: same contract (its cache is bigger — w2 + the
    // codebook transpose — so a miss would be costlier).
    let m = Csr::random(40, 12, 0.3, &mut rng);
    let scfg = small_cfg(KernelType::SparseCpu, 10, 8); // 5 chunks/epoch
    let mut s = session(&scfg);
    for _ in 0..steps {
        let mut src = somoclu::io::InMemorySource::new(DataShard::Sparse(m.view()), 8);
        s.step_epoch_source(&mut src).unwrap();
    }
    let (hits, misses) = s.kernel_cache_stats().expect("cpu kernel tracks stats");
    assert_eq!(misses, 0);
    assert_eq!(hits, (steps * 5) as u64);
}

#[test]
fn project_serves_training_and_heldout_data() {
    let mut rng = Rng::new(908);
    let (data, _) = somoclu::data::gaussian_blobs(60, 5, 3, 0.15, &mut rng);
    let cfg = small_cfg(KernelType::DenseCpu, 5, 0);
    let mut s = session(&cfg);
    s.fit(DataInput::BorrowedF32 { data: &data, dim: 5 }).unwrap();

    // Held-out batch: projection is defined and in range.
    let (held, _) = somoclu::data::gaussian_blobs(20, 5, 3, 0.15, &mut rng);
    let mapped = s.project(DataInput::BorrowedF32 { data: &held, dim: 5 }).unwrap();
    assert_eq!(mapped.len(), 20);
    assert!(mapped.iter().all(|&b| (b as usize) < 36));

    // Projection does not mutate the trained state.
    let before = s.codebook().unwrap().weights.clone();
    let epoch_before = s.epoch();
    let _ = s.project(DataInput::BorrowedF32 { data: &held, dim: 5 }).unwrap();
    assert_bits_eq(&before, &s.codebook().unwrap().weights, "project mutated weights");
    assert_eq!(s.epoch(), epoch_before, "project advanced the cursor");

    // A resumed session projects identically to the original.
    let dir = tmpdir("project");
    let ckpt = dir.join("trained.somc");
    s.save_checkpoint(&ckpt).unwrap();
    let mut r = Som::resume(&ckpt).unwrap();
    let a = s.project(DataInput::BorrowedF32 { data: &held, dim: 5 }).unwrap();
    let b = r.project(DataInput::BorrowedF32 { data: &held, dim: 5 }).unwrap();
    assert_eq!(a, b);
}
