//! I/O backend equivalence (ISSUE 3 acceptance): the mmap and pread
//! sources must yield **bit-identical** chunk streams and exact-equal
//! BMUs/accumulators vs the buffered binary path — across random chunk
//! sizes and rank shards, including windows that straddle page
//! boundaries — and `--io pread` must hold exactly one fd for the data
//! file no matter how many ranks stream it.
//!
//! Tests that need the real mmap backend skip themselves (with a
//! notice) when `somoclu::io::mmap::SUPPORTED` is false, so the
//! `--no-default-features` CI leg still runs this suite and proves the
//! buffered/pread fallback plus the stub's clean error.

use somoclu::cluster::netmodel::NetModel;
use somoclu::cluster::runner::{ClusterReport, StreamInput};
use somoclu::coordinator::config::{IoMode, TrainConfig};
use somoclu::coordinator::train::TrainResult;
use somoclu::session::Som;
use somoclu::io::binary::{write_binary_dense, write_binary_sparse, HEADER_LEN};
use somoclu::io::stream::DataSource;
use somoclu::io::{
    BinaryDenseFileSource, BinarySparseFileSource, MappedContainer, MmapDenseSource,
    MmapSparseSource, SharedFd,
};
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::{DataShard, KernelType, TrainingKernel};
use somoclu::prop_assert;
use somoclu::som::{Grid, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::prop::{self, Config};
use somoclu::util::rng::Rng;

const MMAP_OK: bool = somoclu::io::mmap::SUPPORTED;

/// Single-process resident training through the session API.
fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
}

/// Out-of-core training through the session API.
fn fit_source(
    cfg: &TrainConfig,
    source: &mut dyn DataSource,
) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_source(source)
}

/// Multi-rank streaming through the session API.
fn fit_cluster_stream(
    cfg: &TrainConfig,
    input: StreamInput,
    net: NetModel,
) -> anyhow::Result<(TrainResult, ClusterReport)> {
    Som::builder()
        .config(cfg.clone())
        .net(net)
        .build()?
        .fit_cluster_stream(input)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("somoclu_iobk_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Drain dense chunks as raw bit patterns (exact comparison currency).
fn drain_dense_bits(src: &mut dyn DataSource) -> Vec<u32> {
    // Queried before the loop: a live chunk borrows the source.
    let want_dim = src.dim();
    let mut out = Vec::new();
    while let Some(chunk) = src.next_chunk().unwrap() {
        let DataShard::Dense { data, dim } = chunk else {
            panic!("expected dense chunks");
        };
        assert_eq!(dim, want_dim);
        out.extend(data.iter().map(|v| v.to_bits()));
    }
    out
}

/// Drain sparse chunks as (indptr, indices, value-bits) triplets.
fn drain_sparse_exact(src: &mut dyn DataSource) -> (Vec<usize>, Vec<u32>, Vec<u32>) {
    let (mut ips, mut idx, mut vals) = (vec![0usize], Vec::new(), Vec::new());
    while let Some(chunk) = src.next_chunk().unwrap() {
        let DataShard::Sparse(m) = chunk else {
            panic!("expected sparse chunks");
        };
        assert_eq!(m.indptr[0], 0, "chunk indptr not rebased");
        let base = *ips.last().unwrap();
        ips.extend(m.indptr[1..].iter().map(|p| base + p));
        idx.extend_from_slice(m.indices);
        vals.extend(m.values.iter().map(|v| v.to_bits()));
    }
    (ips, idx, vals)
}

/// Every backend's source for one dense rank shard.
fn dense_backend_sources(
    bin: &std::path::Path,
    chunk: usize,
    rank: usize,
    ranks: usize,
) -> Vec<(&'static str, Box<dyn DataSource + Send>)> {
    let mut out: Vec<(&'static str, Box<dyn DataSource + Send>)> = vec![
        (
            "buffered",
            Box::new(BinaryDenseFileSource::open_shard(bin, chunk, rank, ranks).unwrap()),
        ),
        (
            "pread",
            Box::new(
                SharedFd::open(bin)
                    .unwrap()
                    .dense_shard(chunk, rank, ranks)
                    .unwrap(),
            ),
        ),
    ];
    if MMAP_OK {
        out.push((
            "mmap",
            Box::new(
                MappedContainer::open(bin)
                    .unwrap()
                    .dense_shard(chunk, rank, ranks)
                    .unwrap(),
            ),
        ));
    }
    out
}

fn sparse_backend_sources(
    bin: &std::path::Path,
    chunk: usize,
    rank: usize,
    ranks: usize,
) -> Vec<(&'static str, Box<dyn DataSource + Send>)> {
    let mut out: Vec<(&'static str, Box<dyn DataSource + Send>)> = vec![
        (
            "buffered",
            Box::new(BinarySparseFileSource::open_shard(bin, chunk, rank, ranks).unwrap()),
        ),
        (
            "pread",
            Box::new(
                SharedFd::open(bin)
                    .unwrap()
                    .sparse_shard(chunk, rank, ranks)
                    .unwrap(),
            ),
        ),
    ];
    if MMAP_OK {
        out.push((
            "mmap",
            Box::new(
                MappedContainer::open(bin)
                    .unwrap()
                    .sparse_shard(chunk, rank, ranks)
                    .unwrap(),
            ),
        ));
    }
    out
}

// ---------------------------------------------------------------------
// Bit-identical chunk streams, random chunk sizes + rank shards
// ---------------------------------------------------------------------

#[test]
fn prop_backends_bit_identical_chunk_streams() {
    prop::check_with(
        Config {
            cases: 25,
            ..Default::default()
        },
        "io-backend-chunk-equality",
        |g| {
            let rows = g.usize_in(1, 40);
            let dim = g.usize_in(1, 11);
            let chunk = g.usize_in(0, rows + 3);
            let ranks = g.usize_in(1, rows.min(4));
            let mut rng = Rng::new(g.rng.next_u64());

            let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
            let dbin = tmp("prop_dense.somb");
            write_binary_dense(&dbin, rows, dim, &data).map_err(|e| e.to_string())?;
            for rank in 0..ranks {
                let mut streams = Vec::new();
                for (name, mut src) in dense_backend_sources(&dbin, chunk, rank, ranks) {
                    // Two passes: reset must replay identically.
                    let first = drain_dense_bits(&mut src);
                    src.reset().map_err(|e| e.to_string())?;
                    let second = drain_dense_bits(&mut src);
                    prop_assert!(first == second, "{name}: reset replay differs");
                    streams.push((name, first));
                }
                for (name, bits) in &streams[1..] {
                    prop_assert!(
                        *bits == streams[0].1,
                        "dense {name} != buffered (rows {rows} dim {dim} chunk \
                         {chunk} rank {rank}/{ranks})"
                    );
                }
            }

            let m = Csr::random(rows, dim.max(2), 0.4, &mut rng);
            let sbin = tmp("prop_sparse.somb");
            write_binary_sparse(&sbin, &m).map_err(|e| e.to_string())?;
            for rank in 0..ranks {
                let mut streams = Vec::new();
                for (name, mut src) in sparse_backend_sources(&sbin, chunk, rank, ranks) {
                    let first = drain_sparse_exact(&mut src);
                    src.reset().map_err(|e| e.to_string())?;
                    let second = drain_sparse_exact(&mut src);
                    prop_assert!(first == second, "{name}: reset replay differs");
                    streams.push((name, first));
                }
                for (name, triple) in &streams[1..] {
                    prop_assert!(
                        *triple == streams[0].1,
                        "sparse {name} != buffered (rows {rows} chunk {chunk} \
                         rank {rank}/{ranks})"
                    );
                }
            }
            Ok(())
        },
    );
}

/// Rank windows whose byte ranges start mid-page: dim 13 gives 52-byte
/// rows, so with a 40-byte header no window after rank 0 starts
/// page-aligned, and every window spans multiple 4096-byte pages.
#[test]
fn rank_shards_straddle_page_boundaries() {
    let (rows, dim) = (700usize, 13usize);
    let mut rng = Rng::new(91);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("straddle.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    let ranks = 3;
    let mut all = Vec::new();
    for rank in 0..ranks {
        let splits = somoclu::util::threadpool::split_ranges(rows, ranks);
        let w = &splits[rank];
        let byte0 = HEADER_LEN as usize + 4 * w.start * dim;
        let byte1 = HEADER_LEN as usize + 4 * w.end * dim;
        if rank > 0 {
            assert_ne!(byte0 % 4096, 0, "window unexpectedly page-aligned");
        }
        assert!(byte1 - byte0 > 4096, "window does not straddle a page");

        let streams: Vec<_> = dense_backend_sources(&bin, 64, rank, ranks)
            .into_iter()
            .map(|(name, mut src)| (name, drain_dense_bits(&mut src)))
            .collect();
        for (name, bits) in &streams[1..] {
            assert_eq!(*bits, streams[0].1, "{name} rank {rank}");
        }
        all.extend(streams[0].1.clone());
    }
    // Shards concatenate to exactly the file.
    assert_eq!(all, data.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
}

// ---------------------------------------------------------------------
// Exact-equal BMUs and accumulators
// ---------------------------------------------------------------------

#[test]
fn backends_produce_identical_bmus_and_accumulators() {
    let (rows, dim) = (60usize, 9usize);
    let mut rng = Rng::new(92);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("accum.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    let grid = Grid::new(6, 6, GridType::Square, MapType::Planar);
    let cb = somoclu::som::Codebook::random_init(36, dim, &mut rng);
    let nb = Neighborhood::gaussian(false);

    let accumulate = |src: &mut dyn DataSource| {
        let mut kernel = DenseCpuKernel::new(2);
        kernel.epoch_begin(&cb).unwrap();
        let mut bmus = Vec::new();
        let mut num: Vec<u32> = Vec::new();
        let mut den: Vec<u32> = Vec::new();
        let mut parts = 0;
        while let Some(chunk) = src.next_chunk().unwrap() {
            let part = kernel
                .epoch_accumulate(chunk, &cb, &grid, nb, 2.5, 0.9)
                .unwrap();
            bmus.extend(part.bmus);
            if parts == 0 {
                num = part.num.iter().map(|v| v.to_bits()).collect();
                den = part.den.iter().map(|v| v.to_bits()).collect();
            } else {
                // Chunk-count parity: merge order is identical across
                // backends, so compare the raw per-chunk partials too.
                for (a, b) in num.iter_mut().zip(&part.num) {
                    *a ^= b.to_bits();
                }
                for (a, b) in den.iter_mut().zip(&part.den) {
                    *a ^= b.to_bits();
                }
            }
            parts += 1;
        }
        (bmus, num, den, parts)
    };

    let mut reference = None;
    for (name, mut src) in dense_backend_sources(&bin, 17, 0, 1) {
        let got = accumulate(&mut *src);
        match &reference {
            None => reference = Some((name, got)),
            Some((_, want)) => assert_eq!(&got, want, "{name} accumulators diverged"),
        }
    }
}

#[test]
fn backends_train_to_identical_results() {
    let (rows, dim) = (80usize, 6usize);
    let mut rng = Rng::new(93);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("train.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    let cfg = TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 4,
        threads: 2,
        chunk_rows: 11,
        radius0: Some(3.0),
        ..Default::default()
    };

    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    for (name, mut src) in dense_backend_sources(&bin, cfg.chunk_rows, 0, 1) {
        let res = fit_source(&cfg, &mut src).unwrap();
        let weights: Vec<u32> = res.codebook.weights.iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some((res.bmus, weights)),
            Some((bmus, want)) => {
                assert_eq!(&res.bmus, bmus, "{name}: BMUs diverged");
                assert_eq!(&weights, want, "{name}: codebook bits diverged");
            }
        }
    }

    // Sparse: same exactness through the sparse kernel.
    let m = Csr::random(70, 20, 0.25, &mut rng);
    let sbin = tmp("train_sparse.somb");
    write_binary_sparse(&sbin, &m).unwrap();
    let scfg = TrainConfig {
        kernel: KernelType::SparseCpu,
        chunk_rows: 13,
        ..cfg.clone()
    };
    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    for (name, mut src) in sparse_backend_sources(&sbin, scfg.chunk_rows, 0, 1) {
        let res = fit_source(&scfg, &mut src).unwrap();
        let weights: Vec<u32> = res.codebook.weights.iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some((res.bmus, weights)),
            Some((bmus, want)) => {
                assert_eq!(&res.bmus, bmus, "{name}: sparse BMUs diverged");
                assert_eq!(&weights, want, "{name}: sparse codebook bits diverged");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Cluster streaming through the new backends
// ---------------------------------------------------------------------

#[test]
fn cluster_stream_backends_match_single_rank() {
    let (rows, dim) = (90usize, 5usize);
    let mut rng = Rng::new(94);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("cluster.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    let base = TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 5,
        threads: 1,
        radius0: Some(3.0),
        ..Default::default()
    };
    let single = fit(
        &base,
        DataShard::Dense {
            data: &data,
            dim,
        },
    )
    .unwrap();

    for io in [IoMode::Buffered, IoMode::Pread, IoMode::Mmap] {
        if io == IoMode::Mmap && !MMAP_OK {
            eprintln!("skipping --io mmap leg (no mmap backend in this build)");
            continue;
        }
        let mut cfg = base.clone();
        cfg.ranks = 3;
        cfg.chunk_rows = 8;
        cfg.io_mode = io;
        let (multi, _) = fit_cluster_stream(
            &cfg,
            StreamInput::Binary { path: bin.clone() },
            NetModel::ideal(),
        )
        .unwrap();
        assert_eq!(multi.bmus, single.bmus, "io {io:?}");
        assert!(
            (multi.final_qe() - single.final_qe()).abs() < 1e-6,
            "io {io:?}"
        );
    }
}

#[test]
fn cluster_stream_rejects_text_with_zero_copy_io() {
    let path = tmp("text_io.txt");
    std::fs::write(&path, "1 2\n3 4\n5 6\n").unwrap();
    let mut cfg = TrainConfig {
        rows: 4,
        cols: 4,
        epochs: 2,
        ranks: 2,
        chunk_rows: 1,
        radius0: Some(2.0),
        ..Default::default()
    };
    cfg.io_mode = IoMode::Pread;
    let err = fit_cluster_stream(
        &cfg,
        StreamInput::DenseText { path: path.clone() },
        NetModel::ideal(),
    );
    assert!(err.is_err());
    assert!(format!("{:#}", err.unwrap_err()).contains("binary container"));
}

// ---------------------------------------------------------------------
// One shared fd (the --io pread acceptance bar)
// ---------------------------------------------------------------------

/// Count open fds in this process that resolve to `path`.
#[cfg(target_os = "linux")]
fn fds_pointing_at(path: &std::path::Path) -> usize {
    let want = std::fs::canonicalize(path).unwrap();
    let mut n = 0;
    for entry in std::fs::read_dir("/proc/self/fd").unwrap() {
        let entry = entry.unwrap();
        if let Ok(target) = std::fs::read_link(entry.path()) {
            if target == want {
                n += 1;
            }
        }
    }
    n
}

#[cfg(target_os = "linux")]
#[test]
fn pread_ranks_share_exactly_one_fd() {
    let (rows, dim) = (40usize, 4usize);
    let mut rng = Rng::new(95);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("one_fd.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    // Sanity-check the counter with the buffered mode: N sources, N fds.
    let buffered: Vec<_> = (0..4)
        .map(|rank| BinaryDenseFileSource::open_shard(&bin, 5, rank, 4).unwrap())
        .collect();
    assert_eq!(fds_pointing_at(&bin), 4, "buffered fd count");
    drop(buffered);
    assert_eq!(fds_pointing_at(&bin), 0);

    // pread: one SharedFd, four rank sources, ONE fd — even mid-stream.
    let shared = SharedFd::open(&bin).unwrap();
    let mut sources: Vec<_> = (0..4)
        .map(|rank| shared.dense_shard(5, rank, 4).unwrap())
        .collect();
    drop(shared); // ranks keep the fd alive through their Arc clones
    assert_eq!(fds_pointing_at(&bin), 1, "pread fd count");
    for src in &mut sources {
        let _ = src.next_chunk().unwrap();
    }
    assert_eq!(fds_pointing_at(&bin), 1, "pread fd count mid-stream");
    drop(sources);
    assert_eq!(fds_pointing_at(&bin), 0);

    // mmap holds ZERO fds once mapped (the mapping outlives the fd).
    if MMAP_OK {
        let mapped = MappedContainer::open(&bin).unwrap();
        let mut src = mapped.dense_shard(5, 0, 1).unwrap();
        assert_eq!(fds_pointing_at(&bin), 0, "mmap fd count");
        let _ = src.next_chunk().unwrap();
    }
}

// ---------------------------------------------------------------------
// mmap-specific behavior
// ---------------------------------------------------------------------

#[test]
fn mmap_stub_or_backend_behaves() {
    let (rows, dim) = (10usize, 3usize);
    let mut rng = Rng::new(96);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("stub.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    match MmapDenseSource::open(&bin, 4) {
        Ok(mut src) => {
            assert!(MMAP_OK, "stub open unexpectedly succeeded");
            assert_eq!((src.rows(), src.dim()), (rows, dim));
            assert_eq!(
                drain_dense_bits(&mut src),
                data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        Err(e) => {
            assert!(!MMAP_OK, "real backend failed: {e:#}");
            // The fallback must be actionable, not a panic.
            assert!(format!("{e:#}").contains("--io pread"));
            assert!(MmapSparseSource::open(&bin, 4).is_err());
            assert!(MappedContainer::open(&bin).is_err());
        }
    }
}

/// A full-file mapped window is addressable, so PCA init — refused by
/// every other file-backed source — works while still streaming chunks.
#[test]
fn mmap_dense_supports_pca_init() {
    if !MMAP_OK {
        eprintln!("skipping (no mmap backend in this build)");
        return;
    }
    let (rows, dim) = (50usize, 4usize);
    let mut rng = Rng::new(97);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("pca.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();

    let cfg = TrainConfig {
        rows: 5,
        cols: 5,
        epochs: 3,
        threads: 1,
        chunk_rows: 7,
        initialization: somoclu::coordinator::config::Initialization::Pca,
        radius0: Some(2.5),
        ..Default::default()
    };
    // Resident reference: PCA init over the same data.
    let resident = fit(
        &cfg,
        DataShard::Dense {
            data: &data,
            dim,
        },
    )
    .unwrap();
    let mut src = MmapDenseSource::open(&bin, cfg.chunk_rows).unwrap();
    let streamed = fit_source(&cfg, &mut src).unwrap();
    assert_eq!(streamed.bmus, resident.bmus);

    // A rank window (not the whole file) must NOT claim residency.
    let mapped = MappedContainer::open(&bin).unwrap();
    let shard = mapped.dense_shard(7, 1, 2).unwrap();
    assert!(shard.resident().is_none());
}
