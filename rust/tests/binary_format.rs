//! Binary container format (ISSUE 2): text → `convert` → binary streams
//! must be **bit-identical** to text-parsed streams — same rows, same
//! BMUs, same Eq. 6 accumulators — and corrupt/truncated containers must
//! be rejected at open, before any training runs.

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::session::Som;
use somoclu::io::binary::{
    self, convert_dense_to_binary, convert_sparse_to_binary, write_binary_dense,
    write_binary_sparse, BinaryKind, HEADER_LEN,
};
use somoclu::io::stream::{DataSource, PrefetchSource};
use somoclu::io::{
    dense, sparse as sparse_io, BinaryDenseFileSource, BinarySparseFileSource,
    ChunkedDenseFileSource, ChunkedSparseFileSource,
};
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::{DataShard, KernelType, TrainingKernel};
use somoclu::prop_assert;
use somoclu::som::{Grid, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::prop::{self, Config};
use somoclu::util::rng::Rng;

/// Out-of-core training through the session API.
fn fit_source(
    cfg: &TrainConfig,
    source: &mut dyn DataSource,
) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_source(source)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("somoclu_binfmt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Drain dense chunks as raw bit patterns (exact comparison currency).
fn drain_dense_bits(src: &mut dyn DataSource) -> Vec<u32> {
    let mut out = Vec::new();
    while let Some(chunk) = src.next_chunk().unwrap() {
        let DataShard::Dense { data, .. } = chunk else {
            panic!("expected dense chunks");
        };
        out.extend(data.iter().map(|v| v.to_bits()));
    }
    out
}

/// Drain sparse chunks as (indptr, indices, value-bits) triplets.
fn drain_sparse_exact(src: &mut dyn DataSource) -> (Vec<usize>, Vec<u32>, Vec<u32>) {
    let (mut ips, mut idx, mut vals) = (vec![0usize], Vec::new(), Vec::new());
    while let Some(chunk) = src.next_chunk().unwrap() {
        let DataShard::Sparse(m) = chunk else {
            panic!("expected sparse chunks");
        };
        let base = *ips.last().unwrap();
        ips.extend(m.indptr[1..].iter().map(|p| base + p));
        idx.extend_from_slice(&m.indices);
        vals.extend(m.values.iter().map(|v| v.to_bits()));
    }
    (ips, idx, vals)
}

// ---------------------------------------------------------------------
// Round trips
// ---------------------------------------------------------------------

#[test]
fn dense_text_convert_binary_chunks_bit_identical() {
    let mut rng = Rng::new(70);
    let (rows, dim) = (57, 7);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let txt = tmp("rt_dense.txt");
    dense::write_dense(&txt, rows, dim, &data, true).unwrap();
    let bin = tmp("rt_dense.somb");
    let mut src = ChunkedDenseFileSource::open(&txt, 16).unwrap();
    assert_eq!(convert_dense_to_binary(&mut src, &bin).unwrap(), (rows, dim));
    assert_eq!(binary::sniff(&bin).unwrap(), Some(BinaryKind::Dense));
    assert_eq!(binary::sniff(&txt).unwrap(), None);

    for chunk_rows in [0usize, 1, 13, 57, 100] {
        let mut from_text = ChunkedDenseFileSource::open(&txt, chunk_rows).unwrap();
        let mut from_bin = BinaryDenseFileSource::open(&bin, chunk_rows).unwrap();
        assert_eq!(
            (from_bin.rows(), from_bin.dim()),
            (from_text.rows(), from_text.dim())
        );
        let want = drain_dense_bits(&mut from_text);
        assert_eq!(drain_dense_bits(&mut from_bin), want, "chunk_rows={chunk_rows}");
        // Second epoch identical.
        from_bin.reset().unwrap();
        assert_eq!(drain_dense_bits(&mut from_bin), want);
    }
}

#[test]
fn sparse_text_convert_binary_chunks_bit_identical() {
    let mut rng = Rng::new(71);
    let m = Csr::random(41, 19, 0.25, &mut rng);
    let txt = tmp("rt_sparse.svm");
    sparse_io::write_sparse(&txt, &m).unwrap();
    let bin = tmp("rt_sparse.somb");
    let mut src = ChunkedSparseFileSource::open(&txt, 19, 8).unwrap();
    let (rows, cols, nnz) = convert_sparse_to_binary(&mut src, &bin).unwrap();
    assert_eq!((rows, cols), (src.rows(), 19));
    assert_eq!(nnz, m.nnz());
    assert_eq!(binary::sniff(&bin).unwrap(), Some(BinaryKind::Sparse));

    for chunk_rows in [0usize, 1, 6, 41] {
        let mut from_text = ChunkedSparseFileSource::open(&txt, 19, chunk_rows).unwrap();
        let mut from_bin = BinarySparseFileSource::open(&bin, chunk_rows).unwrap();
        assert_eq!(from_bin.rows(), from_text.rows());
        assert_eq!(from_bin.dim(), from_text.dim());
        let want = drain_sparse_exact(&mut from_text);
        assert_eq!(
            drain_sparse_exact(&mut from_bin),
            want,
            "chunk_rows={chunk_rows}"
        );
    }
}

#[test]
fn direct_writers_round_trip() {
    let mut rng = Rng::new(72);
    let (rows, dim) = (12, 5);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("direct_dense.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();
    let mut src = BinaryDenseFileSource::open(&bin, 0).unwrap();
    let bits: Vec<u32> = data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(drain_dense_bits(&mut src), bits);

    let m = Csr::random(9, 6, 0.5, &mut rng);
    let sbin = tmp("direct_sparse.somb");
    write_binary_sparse(&sbin, &m).unwrap();
    let mut src = BinarySparseFileSource::open(&sbin, 4).unwrap();
    let (ips, idx, vals) = drain_sparse_exact(&mut src);
    assert_eq!(ips, m.indptr);
    assert_eq!(idx, m.indices);
    assert_eq!(vals, m.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
}

#[test]
fn prop_convert_round_trip_bit_identical() {
    prop::check_with(
        Config {
            cases: 25,
            ..Default::default()
        },
        "binary-convert-roundtrip",
        |g| {
            let rows = g.usize_in(1, 24);
            let cols = g.usize_in(1, 10);
            let chunk = g.usize_in(0, rows + 4);
            let data = g.vec_f32(rows * cols, -1e3, 1e3);
            let txt = tmp("prop_dense.txt");
            dense::write_dense(&txt, rows, cols, &data, false)
                .map_err(|e| e.to_string())?;
            let bin = tmp("prop_dense.somb");
            let mut src =
                ChunkedDenseFileSource::open(&txt, 5).map_err(|e| e.to_string())?;
            convert_dense_to_binary(&mut src, &bin).map_err(|e| e.to_string())?;
            let mut a = ChunkedDenseFileSource::open(&txt, chunk)
                .map_err(|e| e.to_string())?;
            let mut b =
                BinaryDenseFileSource::open(&bin, chunk).map_err(|e| e.to_string())?;
            prop_assert!(
                drain_dense_bits(&mut a) == drain_dense_bits(&mut b),
                "dense bits differ (rows {rows} cols {cols} chunk {chunk})"
            );

            // Sparse: random CSR through the same pipeline.
            let mut rng = Rng::new(g.rng.next_u64());
            let m = Csr::random(rows, cols.max(2), 0.5, &mut rng);
            let svm = tmp("prop_sparse.svm");
            sparse_io::write_sparse(&svm, &m).map_err(|e| e.to_string())?;
            let sbin = tmp("prop_sparse.somb");
            let mut src = ChunkedSparseFileSource::open(&svm, m.cols, 4)
                .map_err(|e| e.to_string())?;
            convert_sparse_to_binary(&mut src, &sbin).map_err(|e| e.to_string())?;
            let mut a = ChunkedSparseFileSource::open(&svm, m.cols, chunk)
                .map_err(|e| e.to_string())?;
            let mut b = BinarySparseFileSource::open(&sbin, chunk)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                drain_sparse_exact(&mut a) == drain_sparse_exact(&mut b),
                "sparse sections differ (rows {rows} chunk {chunk})"
            );
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Kernel-level equality: BMUs and accumulators
// ---------------------------------------------------------------------

#[test]
fn binary_chunks_produce_identical_accumulators() {
    let mut rng = Rng::new(73);
    let (rows, dim) = (48, 6);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let txt = tmp("accum.txt");
    dense::write_dense(&txt, rows, dim, &data, false).unwrap();
    let bin = tmp("accum.somb");
    let mut src = ChunkedDenseFileSource::open(&txt, 9).unwrap();
    convert_dense_to_binary(&mut src, &bin).unwrap();

    let grid = Grid::new(5, 5, GridType::Square, MapType::Planar);
    let cb = somoclu::som::Codebook::random_init(grid.node_count(), dim, &mut rng);
    let run = |src: &mut dyn DataSource| {
        let mut kernel = DenseCpuKernel::new(2);
        kernel.epoch_begin(&cb).unwrap();
        let mut accums = Vec::new();
        while let Some(chunk) = src.next_chunk().unwrap() {
            accums.push(
                kernel
                    .epoch_accumulate(
                        chunk,
                        &cb,
                        &grid,
                        Neighborhood::gaussian(false),
                        2.0,
                        1.0,
                    )
                    .unwrap(),
            );
        }
        accums
    };
    let mut text_src = ChunkedDenseFileSource::open(&txt, 9).unwrap();
    let mut bin_src = BinaryDenseFileSource::open(&bin, 9).unwrap();
    let a = run(&mut text_src);
    let b = run(&mut bin_src);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        // Same bits in → same bits out: exact, not tolerance-based.
        assert_eq!(x.bmus, y.bmus);
        assert_eq!(x.num, y.num);
        assert_eq!(x.den, y.den);
        assert_eq!(x.qe_sum.to_bits(), y.qe_sum.to_bits());
    }
}

#[test]
fn binary_and_prefetch_training_matches_text_training() {
    let mut rng = Rng::new(74);
    let (rows, dim) = (120, 8);
    let (data, _) = somoclu::data::gaussian_blobs(rows, dim, 4, 0.2, &mut rng);
    let txt = tmp("train.txt");
    dense::write_dense(&txt, rows, dim, &data, false).unwrap();
    let bin = tmp("train.somb");
    let mut src = ChunkedDenseFileSource::open(&txt, 32).unwrap();
    convert_dense_to_binary(&mut src, &bin).unwrap();

    let cfg = TrainConfig {
        rows: 7,
        cols: 7,
        epochs: 5,
        threads: 2,
        radius0: Some(3.5),
        ..Default::default()
    };
    let mut text_src = ChunkedDenseFileSource::open(&txt, 17).unwrap();
    let want = fit_source(&cfg, &mut text_src).unwrap();

    let mut bin_src = BinaryDenseFileSource::open(&bin, 17).unwrap();
    let got = fit_source(&cfg, &mut bin_src).unwrap();
    assert_eq!(got.bmus, want.bmus);
    assert_eq!(got.codebook.weights, want.codebook.weights);

    let mut pf = PrefetchSource::new(BinaryDenseFileSource::open(&bin, 17).unwrap());
    let got = fit_source(&cfg, &mut pf).unwrap();
    assert_eq!(got.bmus, want.bmus);
    assert_eq!(got.codebook.weights, want.codebook.weights);
}

#[test]
fn sparse_binary_training_matches_text_training() {
    let mut rng = Rng::new(75);
    let m = Csr::random(90, 30, 0.15, &mut rng);
    let svm = tmp("train.svm");
    sparse_io::write_sparse(&svm, &m).unwrap();
    let bin = tmp("train_sp.somb");
    let mut src = ChunkedSparseFileSource::open(&svm, 30, 20).unwrap();
    convert_sparse_to_binary(&mut src, &bin).unwrap();

    let cfg = TrainConfig {
        rows: 6,
        cols: 6,
        epochs: 4,
        kernel: KernelType::SparseCpu,
        threads: 2,
        radius0: Some(3.0),
        ..Default::default()
    };
    let mut text_src = ChunkedSparseFileSource::open(&svm, 30, 13).unwrap();
    let want = fit_source(&cfg, &mut text_src).unwrap();
    let mut bin_src = BinarySparseFileSource::open(&bin, 13).unwrap();
    let got = fit_source(&cfg, &mut bin_src).unwrap();
    assert_eq!(got.bmus, want.bmus);
    assert_eq!(got.codebook.weights, want.codebook.weights);

    let mut pf = PrefetchSource::new(BinarySparseFileSource::open(&bin, 13).unwrap());
    let got = fit_source(&cfg, &mut pf).unwrap();
    assert_eq!(got.bmus, want.bmus);
}

// ---------------------------------------------------------------------
// Rank shards over binary containers
// ---------------------------------------------------------------------

#[test]
fn binary_shards_are_disjoint_and_cover_file() {
    let mut rng = Rng::new(76);
    let (rows, dim) = (37, 5);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let bin = tmp("shards.somb");
    write_binary_dense(&bin, rows, dim, &data).unwrap();
    let bits: Vec<u32> = data.iter().map(|v| v.to_bits()).collect();
    for ranks in [1usize, 2, 3, 5] {
        let mut all = Vec::new();
        for rank in 0..ranks {
            let mut src =
                BinaryDenseFileSource::open_shard(&bin, 4, rank, ranks).unwrap();
            all.extend(drain_dense_bits(&mut src));
        }
        assert_eq!(all, bits, "ranks={ranks}");
    }
    assert!(BinaryDenseFileSource::open_shard(&bin, 4, 0, rows + 1).is_err());
    assert!(BinaryDenseFileSource::open_shard(&bin, 4, 3, 3).is_err());
}

#[test]
fn sparse_binary_shards_cover_file() {
    let mut rng = Rng::new(77);
    let m = Csr::random(29, 11, 0.3, &mut rng);
    let bin = tmp("shards_sp.somb");
    write_binary_sparse(&bin, &m).unwrap();
    let mut whole = BinarySparseFileSource::open(&bin, 0).unwrap();
    let want = drain_sparse_exact(&mut whole);
    for ranks in [2usize, 4] {
        let (mut ips, mut idx, mut vals) = (vec![0usize], Vec::new(), Vec::new());
        for rank in 0..ranks {
            let mut src =
                BinarySparseFileSource::open_shard(&bin, 6, rank, ranks).unwrap();
            let (i, x, v) = drain_sparse_exact(&mut src);
            let base = *ips.last().unwrap();
            ips.extend(i[1..].iter().map(|p| base + p));
            idx.extend(x);
            vals.extend(v);
        }
        assert_eq!((ips, idx, vals), want, "ranks={ranks}");
    }
}

// ---------------------------------------------------------------------
// Corruption / rejection
// ---------------------------------------------------------------------

#[test]
fn truncated_and_corrupt_containers_rejected_at_open() {
    let mut rng = Rng::new(78);
    let (rows, dim) = (10, 4);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let good = tmp("good.somb");
    write_binary_dense(&good, rows, dim, &data).unwrap();
    let bytes = std::fs::read(&good).unwrap();

    // Shorter than the header.
    let p = tmp("short.somb");
    std::fs::write(&p, &bytes[..10]).unwrap();
    assert!(BinaryDenseFileSource::open(&p, 4).is_err());

    // Truncated payload (header intact, rows missing).
    let p = tmp("trunc.somb");
    std::fs::write(&p, &bytes[..bytes.len() - 7]).unwrap();
    assert!(BinaryDenseFileSource::open(&p, 4).is_err());

    // Trailing garbage (file longer than the header declares).
    let p = tmp("padded.somb");
    let mut padded = bytes.clone();
    padded.extend_from_slice(&[0u8; 5]);
    std::fs::write(&p, &padded).unwrap();
    assert!(BinaryDenseFileSource::open(&p, 4).is_err());

    // Bad magic.
    let p = tmp("magic.somb");
    let mut bad = bytes.clone();
    bad[0] = b'X';
    std::fs::write(&p, &bad).unwrap();
    assert!(BinaryDenseFileSource::open(&p, 4).is_err());
    assert_eq!(binary::sniff(&p).unwrap(), None);

    // Unsupported version.
    let p = tmp("version.somb");
    let mut bad = bytes.clone();
    bad[4] = 99;
    std::fs::write(&p, &bad).unwrap();
    assert!(BinaryDenseFileSource::open(&p, 4).is_err());

    // Nonzero reserved field.
    let p = tmp("reserved.somb");
    let mut bad = bytes.clone();
    bad[12] = 1;
    std::fs::write(&p, &bad).unwrap();
    assert!(BinaryDenseFileSource::open(&p, 4).is_err());

    // Kind mismatch: a dense container is not a sparse source and
    // vice versa.
    assert!(BinarySparseFileSource::open(&good, 4).is_err());
    let m = Csr::random(5, 4, 0.5, &mut rng);
    let sp = tmp("good_sp.somb");
    write_binary_sparse(&sp, &m).unwrap();
    assert!(BinaryDenseFileSource::open(&sp, 4).is_err());

    // The intact file still opens after all this.
    assert!(BinaryDenseFileSource::open(&good, 4).is_ok());
}

#[test]
fn corrupt_sparse_sections_rejected_at_read() {
    let mut rng = Rng::new(79);
    let m = Csr::random(8, 6, 0.5, &mut rng);
    let good = tmp("sections.somb");
    write_binary_sparse(&good, &m).unwrap();
    let bytes = std::fs::read(&good).unwrap();

    // Corrupt an indptr entry to be non-monotone (entry 2, after the
    // header at byte 40): chunk reads must fail, not stream garbage.
    let p = tmp("indptr.somb");
    let mut bad = bytes.clone();
    let off = HEADER_LEN as usize + 2 * 8;
    bad[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    std::fs::write(&p, &bad).unwrap();
    let mut src = BinarySparseFileSource::open(&p, 3).unwrap();
    let mut failed = false;
    for _ in 0..4 {
        match src.next_chunk() {
            Err(_) => {
                failed = true;
                break;
            }
            Ok(None) => break,
            Ok(Some(_)) => {}
        }
    }
    assert!(failed, "non-monotone indptr streamed without error");

    // Corrupt a column index out of range.
    let p = tmp("colrange.somb");
    let mut bad = bytes.clone();
    let idx_off = HEADER_LEN as usize + 8 * (m.rows + 1);
    bad[idx_off..idx_off + 4].copy_from_slice(&1_000_000u32.to_le_bytes());
    std::fs::write(&p, &bad).unwrap();
    let mut src = BinarySparseFileSource::open(&p, 0).unwrap();
    assert!(src.next_chunk().is_err());
}

// ---------------------------------------------------------------------
// CLI: convert + binary training end to end
// ---------------------------------------------------------------------

#[test]
fn cli_convert_then_train_matches_text_cli() {
    use std::process::Command;
    let dir = tmp("cli");
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(80);
    let (rows, dim) = (140, 6);
    let (d, _) = somoclu::data::gaussian_blobs(rows, dim, 3, 0.2, &mut rng);
    let txt = dir.join("data.txt");
    dense::write_dense(&txt, rows, dim, &d, false).unwrap();
    let bin = dir.join("data.somb");

    let somoclu = env!("CARGO_BIN_EXE_somoclu");

    // In-place conversion must be refused BEFORE the output truncates
    // the input (File::create on the same path would destroy it).
    let before = std::fs::read(&txt).unwrap();
    let out = Command::new(somoclu)
        .args(["convert", txt.to_str().unwrap(), txt.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "in-place convert must fail");
    assert_eq!(std::fs::read(&txt).unwrap(), before, "input was clobbered");

    let out = Command::new(somoclu)
        .args(["convert", txt.to_str().unwrap(), bin.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "convert failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(binary::sniff(&bin).unwrap(), Some(BinaryKind::Dense));

    let run = |input: &std::path::Path, prefix: &str, extra: &[&str]| {
        let out_prefix = dir.join(prefix);
        let mut args: Vec<String> =
            ["-e", "3", "-x", "8", "-y", "8", "-r", "4", "--seed", "9"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        args.extend(extra.iter().map(|s| s.to_string()));
        args.push(input.to_str().unwrap().to_string());
        args.push(out_prefix.to_str().unwrap().to_string());
        let out = Command::new(somoclu).args(&args).output().unwrap();
        assert!(
            out.status.success(),
            "stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        dense::read_dense(format!("{}.wts", out_prefix.display())).unwrap()
    };

    let from_text = run(&txt, "t", &["--chunk-rows", "40"]);
    let from_bin = run(&bin, "b", &["--chunk-rows", "40"]);
    let prefetched = run(&bin, "p", &["--chunk-rows", "40", "--prefetch"]);
    let ranked = run(&bin, "r", &["--chunk-rows", "40", "--ranks", "3"]);
    for (name, got) in [("binary", &from_bin), ("prefetch", &prefetched), ("ranks", &ranked)] {
        assert_eq!(from_text.rows, got.rows, "{name}");
        for (a, b) in from_text.data.iter().zip(&got.data) {
            assert!((a - b).abs() < 1e-4, "{name}: {a} vs {b}");
        }
    }
}
