//! Integration: full training runs across kernels, geometries and file
//! formats — the paths a somoclu user exercises end to end.

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::io::output::{OutputWriter, SnapshotLevel};
use somoclu::io::{esom, read_dense, InMemorySource};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::session::Som;
use somoclu::som::{quality, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("somoclu_it_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train through the session API (what a library user writes today).
fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
    Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
}

/// [`fit`] warm-started from an explicit initial codebook.
fn fit_with_initial(
    cfg: &TrainConfig,
    shard: DataShard<'_>,
    initial: somoclu::som::Codebook,
) -> anyhow::Result<TrainResult> {
    Som::builder()
        .config(cfg.clone())
        .initial_codebook(initial)
        .build()?
        .fit_shard(shard)
}

#[test]
fn dense_training_produces_topology_preserving_map() {
    let mut rng = Rng::new(100);
    let (train_data, labels) = data::gaussian_blobs(400, 8, 4, 0.15, &mut rng);
    let cfg = TrainConfig {
        rows: 10,
        cols: 10,
        epochs: 12,
        threads: 4,
        radius0: Some(5.0),
        ..Default::default()
    };
    let res = fit(
        &cfg,
        DataShard::Dense {
            data: &train_data,
            dim: 8,
        },
    )
    .unwrap();

    // QE must fall substantially on clustered data.
    assert!(res.epochs.last().unwrap().qe < res.epochs[0].qe * 0.4);

    // Same-cluster rows should map to nearby nodes: mean intra-cluster
    // grid distance << mean cross-cluster distance.
    let grid = cfg.grid();
    let mut intra = (0.0f64, 0usize);
    let mut cross = (0.0f64, 0usize);
    for i in (0..400).step_by(7) {
        for j in (1..400).step_by(11) {
            let d = grid.distance(res.bmus[i] as usize, res.bmus[j] as usize) as f64;
            if labels[i] == labels[j] {
                intra = (intra.0 + d, intra.1 + 1);
            } else {
                cross = (cross.0 + d, cross.1 + 1);
            }
        }
    }
    let (mi, mc) = (intra.0 / intra.1 as f64, cross.0 / cross.1 as f64);
    assert!(mi * 1.5 < mc, "intra {mi} vs cross {mc}");

    // Topographic error should be small on a converged map.
    let te = quality::topographic_error(&train_data, 8, &grid, &res.codebook, 4);
    assert!(te < 0.35, "TE {te}");
}

#[test]
fn outputs_are_esom_compatible() {
    let mut rng = Rng::new(101);
    let (train_data, _) = data::gaussian_blobs(80, 4, 3, 0.2, &mut rng);
    let dir = tmpdir("esom");
    let prefix = dir.join("run");
    let cfg = TrainConfig {
        rows: 6,
        cols: 7,
        epochs: 4,
        threads: 2,
        radius0: Some(3.0),
        snapshot: SnapshotLevel::Full,
        ..Default::default()
    };
    let writer = OutputWriter::new(&prefix);
    let mut session = Som::builder().config(cfg.clone()).build().unwrap();
    let mut src = InMemorySource::new(
        DataShard::Dense {
            data: &train_data,
            dim: 4,
        },
        cfg.chunk_rows,
    );
    // Interim snapshots ride the per-epoch observer hook; the final
    // files are one explicit write — the CLI's exact shape.
    let res = session
        .fit_source_with(&mut src, &mut |s| s.write_epoch_snapshot(&writer))
        .unwrap();
    writer
        .write_final(session.grid(), &res.codebook, &res.bmus, &res.umatrix)
        .unwrap();

    // Final files exist and parse.
    let wts = read_dense(format!("{}.wts", prefix.display())).unwrap();
    assert_eq!(wts.rows, 42);
    assert_eq!(wts.cols, 4);
    assert_eq!(wts.data, res.codebook.weights);

    let bm = esom::read_bm(format!("{}.bm", prefix.display())).unwrap();
    assert_eq!(bm.len(), 80);

    let umx = read_dense(format!("{}.umx", prefix.display())).unwrap();
    assert_eq!((umx.rows, umx.cols), (6, 7));

    // Interim snapshots for every epoch at level 2.
    for epoch in 0..4 {
        for ext in ["umx", "wts", "bm"] {
            let p = format!("{}.{epoch}.{ext}", prefix.display());
            assert!(std::path::Path::new(&p).exists(), "{p}");
        }
    }
}

#[test]
fn sparse_and_dense_kernels_train_identically() {
    // Train twice from the same seed: once dense on densified data, once
    // sparse on the CSR — the *entire run* must match (BMUs bit-for-bit).
    let mut rng = Rng::new(102);
    let m = Csr::random(150, 40, 0.1, &mut rng);
    let dense = m.to_dense();
    let base = TrainConfig {
        rows: 8,
        cols: 8,
        epochs: 6,
        threads: 3,
        radius0: Some(4.0),
        ..Default::default()
    };
    let mut dense_cfg = base.clone();
    dense_cfg.kernel = KernelType::DenseCpu;
    let mut sparse_cfg = base;
    sparse_cfg.kernel = KernelType::SparseCpu;

    let a = fit(
        &dense_cfg,
        DataShard::Dense {
            data: &dense,
            dim: 40,
        },
    )
    .unwrap();
    let b = fit(&sparse_cfg, DataShard::Sparse(m.view())).unwrap();
    assert_eq!(a.bmus, b.bmus);
    for (x, y) in a.codebook.weights.iter().zip(&b.codebook.weights) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

#[test]
fn toroid_map_wraps_clusters() {
    // On a toroid, a 1-D ring of data can wrap without a seam; just
    // verify training runs and the U-matrix exists for all nodes.
    let mut rng = Rng::new(103);
    let (d, _) = data::gaussian_blobs(120, 3, 6, 0.1, &mut rng);
    let cfg = TrainConfig {
        rows: 6,
        cols: 9,
        epochs: 6,
        map_type: MapType::Toroid,
        grid_type: GridType::Hexagonal,
        neighborhood: Neighborhood::gaussian(true),
        threads: 2,
        radius0: Some(3.0),
        ..Default::default()
    };
    let res = fit(&cfg, DataShard::Dense { data: &d, dim: 3 }).unwrap();
    assert_eq!(res.umatrix.len(), 54);
    assert!(res.umatrix.iter().all(|u| u.is_finite()));
    assert!(res.final_qe().is_finite());
}

#[test]
fn emergent_map_feasible_where_baseline_fails() {
    // The paper's emergent-map pitch: more nodes than data instances is
    // fine for somoclu but impossible for kohonen-like init.
    let mut rng = Rng::new(104);
    let (d, _) = data::gaussian_blobs(50, 4, 3, 0.2, &mut rng);
    let grid = somoclu::som::Grid::new(20, 20, GridType::Square, MapType::Planar);
    assert!(somoclu::baseline::kohonen_like_init(&grid, &d, 4, &mut rng).is_err());

    let cfg = TrainConfig {
        rows: 20,
        cols: 20,
        epochs: 4,
        threads: 4,
        radius0: Some(10.0),
        ..Default::default()
    };
    let res = fit(&cfg, DataShard::Dense { data: &d, dim: 4 }).unwrap();
    assert_eq!(res.codebook.nodes, 400);
    assert!(res.final_qe().is_finite());
}

#[test]
fn initial_codebook_resumes_training() {
    // Train 4 epochs; resume from the written codebook; QE keeps falling.
    let mut rng = Rng::new(105);
    let (d, _) = data::gaussian_blobs(100, 5, 4, 0.2, &mut rng);
    let shard = DataShard::Dense { data: &d, dim: 5 };
    let cfg = TrainConfig {
        rows: 7,
        cols: 7,
        epochs: 4,
        threads: 2,
        radius0: Some(3.5),
        ..Default::default()
    };
    let first = fit(&cfg, shard).unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.radius0 = Some(1.5);
    let second = fit_with_initial(&cfg2, shard, first.codebook).unwrap();
    assert!(second.final_qe() <= first.epochs[0].qe);
}

#[test]
fn pca_init_converges_faster_initially() {
    // somoclu's `initialization='pca'`: the unfolded start should give a
    // lower first-epoch QE than random init on anisotropic data.
    let mut rng = Rng::new(106);
    let (d, _) = data::gaussian_blobs(300, 10, 4, 0.3, &mut rng);
    let shard = DataShard::Dense { data: &d, dim: 10 };
    let mk = |init| TrainConfig {
        rows: 8,
        cols: 8,
        epochs: 4,
        threads: 2,
        radius0: Some(4.0),
        initialization: init,
        ..Default::default()
    };
    let pca = fit(&mk(somoclu::coordinator::config::Initialization::Pca), shard).unwrap();
    let rnd = fit(&mk(somoclu::coordinator::config::Initialization::Random), shard).unwrap();
    assert!(
        pca.epochs[0].qe < rnd.epochs[0].qe,
        "pca {} vs random {}",
        pca.epochs[0].qe,
        rnd.epochs[0].qe
    );
    assert!(pca.final_qe().is_finite() && rnd.final_qe().is_finite());
}

#[test]
fn pca_init_rejected_for_sparse() {
    let mut rng = Rng::new(107);
    let m = Csr::random(50, 20, 0.2, &mut rng);
    let cfg = TrainConfig {
        rows: 5,
        cols: 5,
        epochs: 2,
        kernel: KernelType::SparseCpu,
        initialization: somoclu::coordinator::config::Initialization::Pca,
        radius0: Some(2.0),
        ..Default::default()
    };
    assert!(fit(&cfg, DataShard::Sparse(m.view())).is_err());
}

#[test]
fn codebook_clustering_recovers_data_clusters() {
    // Train on well-separated blobs, then som.cluster()-style k-means on
    // the codebook: data labels via BMUs must match the true labels (up
    // to permutation).
    let mut rng = Rng::new(108);
    let k = 4;
    let (d, truth) = data::gaussian_blobs(240, 6, k, 0.08, &mut rng);
    let cfg = TrainConfig {
        rows: 8,
        cols: 8,
        epochs: 10,
        threads: 2,
        radius0: Some(4.0),
        ..Default::default()
    };
    let res = fit(&cfg, DataShard::Dense { data: &d, dim: 6 }).unwrap();
    let km = somoclu::som::kmeans::kmeans(&res.codebook, k, 100, &mut rng);
    let labels = somoclu::som::kmeans::data_labels(&km, &res.bmus);

    // Purity: for each predicted cluster, the dominant true label's share.
    let mut agree = 0usize;
    for c in 0..k as u32 {
        let mut counts = vec![0usize; k];
        for (i, &l) in labels.iter().enumerate() {
            if l == c {
                counts[truth[i]] += 1;
            }
        }
        agree += counts.iter().max().copied().unwrap_or(0);
    }
    let purity = agree as f64 / labels.len() as f64;
    assert!(purity > 0.9, "purity {purity}");
}
