//! Compile-only stand-in for the `xla` crate (xla-rs / xla_extension).
//!
//! The somoclu accel and hybrid kernels (`-k 1` / `-k 3`) execute AOT HLO
//! artifacts through PJRT. The real binding needs a local `xla_extension`
//! install, which not every build environment carries, and the crate is
//! not fetchable offline. This stub keeps the whole workspace compiling
//! and type-checked with zero external requirements: the API surface the
//! somoclu runtime uses is reproduced exactly, and every entry point that
//! would touch PJRT returns [`Error::Unavailable`].
//!
//! `Engine::new` calls [`PjRtClient::cpu`] first, so under the stub every
//! accel path fails fast with a clear message — the same graceful-skip
//! behaviour the test suite already has for missing AOT artifacts.
//!
//! To run the accel kernels for real, point the `xla` path dependency in
//! the workspace `Cargo.toml` at an xla-rs checkout instead of this stub.

use std::path::Path;

/// Error type mirroring xla-rs's: convertible into `anyhow::Error`.
#[derive(Debug)]
pub enum Error {
    /// The stub is active; no PJRT runtime is linked in.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} needs the real xla-rs binding (swap the \
                 `xla` path dependency for an xla-rs checkout)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait ElementType: Copy + 'static {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u32 {}

/// PJRT client handle. The stub cannot construct one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("buffer_from_host_buffer"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Host-side literal (tuple or array).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T: ElementType>(&self) -> Result<T> {
        Err(Error::Unavailable("Literal::get_first_element"))
    }
}

/// Parsed HLO module proto (text form).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(err.to_string().contains("xla stub"));
    }
}
