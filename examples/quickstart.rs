//! Quickstart: the README's 60-second tour — generate data, train a map,
//! inspect quality, write ESOM-compatible outputs and a PPM heatmap.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use somoclu::api::DataInput;
use somoclu::data;
use somoclu::io::output::OutputWriter;
use somoclu::session::Som;
use somoclu::som::quality;
use somoclu::util::rng::Rng;
use somoclu::viz;

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::PathBuf::from("out/quickstart");
    std::fs::create_dir_all(&out_dir)?;

    // 1. Data: 2,000 rows of 16-d gaussian blobs (5 clusters).
    let mut rng = Rng::new(7);
    let (train_data, _labels) = data::gaussian_blobs(2000, 16, 5, 0.15, &mut rng);

    // 2. A 20x20 planar square map, 10 epochs (paper defaults otherwise:
    //    gaussian neighborhood, linear cooling 1.0 -> 0.01) — one
    //    builder call configures everything.
    let mut session = Som::builder().map_size(20, 20).epochs(10).build()?;

    // 3. Train through the session API (zero-copy f32 input).
    let t0 = std::time::Instant::now();
    let res = session.fit(DataInput::BorrowedF32 { data: &train_data, dim: 16 })?;
    println!("trained in {:?}", t0.elapsed());
    for e in &res.epochs {
        println!(
            "  epoch {:>2}  radius {:>6.2}  QE {:.5}",
            e.epoch, e.radius, e.qe
        );
    }

    // 4. Quality measures + serving: the trained session answers BMU
    //    lookups for new vectors (the fit/predict shape).
    let grid = session.grid().clone();
    let threads = session.config().threads;
    let te = quality::topographic_error(&train_data, 16, &grid, &res.codebook, threads);
    println!("final QE {:.5}, topographic error {:.3}", res.final_qe(), te);
    let (node, dist) = session.bmu(&train_data[0..16])?;
    println!("first row maps to node {node} at distance {dist:.4}");

    // 4b. Checkpoint the trained map; `Som::resume` (or the CLI's
    //     `--resume`) restores it bit-exactly for serving or more epochs.
    session.save_checkpoint(out_dir.join("map.somc"))?;
    let resumed = Som::resume(out_dir.join("map.somc"))?;
    assert_eq!(
        resumed.codebook().unwrap().weights,
        session.codebook().unwrap().weights
    );
    println!("checkpoint round-trip OK ({})", out_dir.join("map.somc").display());

    // 5. Post-process: cluster the codebook (som.cluster() analog) and
    //    label the data through the BMU mapping.
    let mut km_rng = Rng::new(99);
    let km = somoclu::som::kmeans::kmeans(&res.codebook, 5, 100, &mut km_rng);
    let data_labels = somoclu::som::kmeans::data_labels(&km, &res.bmus);
    println!(
        "codebook k-means: k=5, inertia {:.3}, {} iterations; first data labels {:?}",
        km.inertia,
        km.iterations,
        &data_labels[..8]
    );

    // 6. Outputs: ESOM-compatible files + a U-matrix heatmap.
    let writer = OutputWriter::new(out_dir.join("map"));
    writer.write_final(&grid, &res.codebook, &res.bmus, &res.umatrix)?;
    viz::write_heatmap_ppm(
        out_dir.join("umatrix.ppm"),
        &grid,
        &res.umatrix,
        12,
        Some(&res.bmus),
    )?;
    println!("wrote {}/map.{{wts,bm,umx}} and umatrix.ppm", out_dir.display());
    Ok(())
}
