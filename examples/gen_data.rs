//! Generate the example data files the paper's package ships
//! ("Example files are available in the package", §4.1): `data/rgbs.txt`
//! (the RGB toy set used in the paper's CLI examples), `data/random.dat`
//! (the Python-interface example input) and `data/sparse.svm` (libsvm
//! sparse example), so the README's CLI invocations run verbatim.
//!
//! ```bash
//! cargo run --release --example gen_data
//! ./target/release/somoclu data/rgbs.txt data/rgbs
//! ```

use somoclu::data;
use somoclu::io::{dense, sparse as sparse_io};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("data")?;
    let mut rng = Rng::new(0xDA7A);

    // rgbs.txt — the paper's "$ Somoclu data/rgbs.txt data/rgbs" input.
    let (rgb, _) = data::rgb_toy(3000, &mut rng);
    dense::write_dense("data/rgbs.txt", 3000, 3, &rgb, false)?;
    println!("wrote data/rgbs.txt          (3000 x 3 dense)");

    // random.dat — "data = numpy.loadtxt('data/random.dat')" (§4.3).
    let rand = data::random_dense(2000, 16, &mut rng);
    dense::write_dense("data/random.dat", 2000, 16, &rand, false)?;
    println!("wrote data/random.dat        (2000 x 16 dense)");

    // headered variant (ESOM-compatible dense format).
    dense::write_dense("data/random_header.dat", 2000, 16, &rand, true)?;
    println!("wrote data/random_header.dat (2000 x 16 dense, % header)");

    // sparse.svm — libsvm-format sparse example (§4.1 format).
    let m = Csr::random(1500, 512, 0.04, &mut rng);
    sparse_io::write_sparse("data/sparse.svm", &m)?;
    println!(
        "wrote data/sparse.svm        (1500 x 512 sparse, {:.1}% nonzero)",
        m.density() * 100.0
    );

    println!("\ntry:");
    println!("  ./target/release/somoclu -e 10 -x 20 -y 20 data/rgbs.txt out/rgbs");
    println!("  ./target/release/somoclu -k 2 -x 16 -y 16 data/sparse.svm out/sparse");
    Ok(())
}
