//! End-to-end driver (the required full-stack validation run): exercises
//! ALL THREE LAYERS on a real small workload and logs the loss curve.
//!
//! Pipeline: synthetic clustered data -> rust coordinator -> per-epoch
//! accel (XLA/PJRT) executions of the AOT-lowered JAX+Pallas epoch step
//! -> batch codebook updates -> QE curve + U-matrix + cross-check against
//! the pure-rust dense kernel. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use somoclu::coordinator::config::TrainConfig;
use somoclu::data;
use somoclu::session::Som;
use somoclu::io::output::OutputWriter;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::runtime::Manifest;
use somoclu::som::quality;
use somoclu::util::rng::Rng;
use somoclu::viz;

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::PathBuf::from("out/e2e");
    std::fs::create_dir_all(&out_dir)?;
    anyhow::ensure!(
        Manifest::default_dir().join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // Workload: 4,096 rows, 48 dims, 8 clusters; 24x24 map; 15 epochs.
    let mut rng = Rng::new(23);
    let (dim, n_rows) = (48, 4096);
    let (train_data, labels) = data::gaussian_blobs(n_rows, dim, 8, 0.2, &mut rng);
    let cfg = TrainConfig {
        rows: 24,
        cols: 24,
        epochs: 15,
        kernel: KernelType::Accel,
        radius0: Some(12.0),
        ..Default::default()
    };
    println!(
        "e2e: {n_rows} rows x {dim} dims, 24x24 map, 15 epochs, kernel=accel-xla"
    );

    // Layer check: which artifact will serve this run?
    let manifest = Manifest::load(Manifest::default_dir())?;
    let art = manifest.select_som_step("gaussian", "planar", dim, 24 * 24)?;
    println!(
        "artifact: {} (S={}, D={}, N={}, blocks {}x{})",
        art.name, art.s, art.d, art.n, art.block_s, art.block_n
    );

    let t0 = std::time::Instant::now();
    let res = Som::builder().config(cfg.clone()).build()?.fit_shard(DataShard::Dense {
        data: &train_data,
        dim,
    })?;
    let accel_time = t0.elapsed();
    println!("loss curve (mean quantization error per epoch):");
    for e in &res.epochs {
        let bar = "#".repeat((e.qe * 40.0 / res.epochs[0].qe) as usize);
        println!(
            "  epoch {:>2}  radius {:>6.2}  QE {:.5}  {bar}",
            e.epoch, e.radius, e.qe
        );
    }

    // Cross-layer verification: same run on the pure-rust dense kernel.
    let mut cpu_cfg = cfg.clone();
    cpu_cfg.kernel = KernelType::DenseCpu;
    let t1 = std::time::Instant::now();
    let cpu = Som::builder()
        .config(cpu_cfg.clone())
        .build()?
        .fit_shard(DataShard::Dense {
            data: &train_data,
            dim,
        })?;
    let cpu_time = t1.elapsed();
    // Cross-layer check 1 — single-epoch equivalence from the same
    // initial codebook: the XLA path and the rust path must produce the
    // same BMUs and the same updated codebook for one `trainOneEpoch`.
    // (Full 15-epoch trajectories diverge chaotically from f32 rounding
    // — both end at equally good maps, so whole-run agreement is checked
    // by quality parity below, exactly like comparing two MPI layouts.)
    let grid = cfg.grid();
    let cb_init = somoclu::coordinator::train::init_codebook(&cfg, &grid, dim);
    let mut sess_a = Som::builder()
        .config(cfg.clone())
        .initial_codebook(cb_init.clone())
        .build()?;
    let mut sess_b = Som::builder()
        .config(cpu_cfg.clone())
        .initial_codebook(cb_init)
        .build()?;
    let stats_a = sess_a.step_epoch(somoclu::api::DataInput::BorrowedF32 {
        data: &train_data,
        dim,
    })?;
    let stats_b = sess_b.step_epoch(somoclu::api::DataInput::BorrowedF32 {
        data: &train_data,
        dim,
    })?;
    let (qe_a, qe_b) = (stats_a.qe, stats_b.qe);
    let (bmus_a, bmus_b) = (sess_a.last_bmus().to_vec(), sess_b.last_bmus().to_vec());
    let cb_a = sess_a.codebook().expect("trained").clone();
    let cb_b = sess_b.codebook().expect("trained").clone();
    let epoch_agree = bmus_a.iter().zip(&bmus_b).filter(|(a, b)| a == b).count();
    let max_w_diff = cb_a
        .weights
        .iter()
        .zip(&cb_b.weights)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "single-epoch cross-check: {epoch_agree}/{} BMUs identical, max \
         codebook delta {max_w_diff:.2e}, QE {qe_a:.6} vs {qe_b:.6}",
        bmus_a.len()
    );
    anyhow::ensure!(
        epoch_agree as f64 >= 0.999 * bmus_a.len() as f64,
        "single-epoch cross-layer disagreement"
    );
    anyhow::ensure!(max_w_diff < 1e-2, "single-epoch codebook divergence");

    // Cross-layer check 2 — full-run quality parity.
    let agree = res
        .bmus
        .iter()
        .zip(&cpu.bmus)
        .filter(|(a, b)| a == b)
        .count();
    let qe_rel = (res.final_qe() - cpu.final_qe()).abs() / cpu.final_qe();
    println!(
        "full-run: {agree}/{} BMUs coincide (informational — trajectories \
         diverge), QE rel diff {:.2e} (accel {:?} vs cpu {:?}; \
         interpret-mode Pallas is expected slower)",
        res.bmus.len(),
        qe_rel,
        accel_time,
        cpu_time
    );
    anyhow::ensure!(qe_rel < 1e-2, "QE diverged across layers");

    // Map quality: clusters should be separated on the grid.
    let grid = cfg.grid();
    let te = quality::topographic_error(&train_data, dim, &grid, &res.codebook, cfg.threads);
    let mut purity_hits = 0usize;
    let mut node_label: Vec<Option<usize>> = vec![None; grid.node_count()];
    let mut occupied = 0usize;
    for (i, &b) in res.bmus.iter().enumerate() {
        match node_label[b as usize] {
            None => {
                node_label[b as usize] = Some(labels[i]);
                occupied += 1;
            }
            Some(l) if l == labels[i] => purity_hits += 1,
            Some(_) => {}
        }
    }
    println!(
        "final QE {:.5}, TE {:.3}, node-label consistency {:.1}%",
        res.final_qe(),
        te,
        100.0 * purity_hits as f64 / (n_rows - occupied) as f64
    );

    OutputWriter::new(out_dir.join("map"))
        .write_final(&grid, &res.codebook, &res.bmus, &res.umatrix)?;
    viz::write_heatmap_ppm(out_dir.join("umatrix.ppm"), &grid, &res.umatrix, 10, Some(&res.bmus))?;
    println!("outputs in {}", out_dir.display());
    println!("E2E OK: all three layers verified on a live training run.");
    Ok(())
}
