//! Distributed training demo — the paper's multi-node mode (§3.2) on the
//! simulated cluster: shard the data over N ranks, train, and report the
//! Fig. 8-style speedup plus communication volume under a modeled 10 GbE
//! interconnect.
//!
//! ```bash
//! cargo run --release --example cluster_train            # 1..8 ranks
//! SOM_RANKS=4 cargo run --release --example cluster_train
//! ```

use somoclu::cluster::netmodel::NetModel;
use somoclu::cluster::runner::ClusterData;
use somoclu::coordinator::config::TrainConfig;
use somoclu::data;
use somoclu::session::Som;
use somoclu::util::memtrack::fmt_bytes;
use somoclu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(17);
    let (rows, dim) = (8_000, 64);
    let (train_data, _) = data::gaussian_blobs(rows, dim, 10, 0.2, &mut rng);
    println!(
        "data: {rows} rows x {dim} dims ({}); map 20x20; 5 epochs; 10GbE model",
        fmt_bytes(rows * dim * 4)
    );

    let rank_list: Vec<usize> = match std::env::var("SOM_RANKS") {
        Ok(v) => vec![v.parse()?],
        Err(_) => vec![1, 2, 4, 8],
    };

    let mut t1 = None;
    println!(
        "{:>6} {:>12} {:>10} {:>14} {:>12} {:>10}",
        "ranks", "time", "speedup", "bytes sent", "msgs", "final QE"
    );
    for ranks in rank_list {
        let cfg = TrainConfig {
            rows: 20,
            cols: 20,
            epochs: 5,
            ranks,
            threads: 1, // one core per rank: pure scaling signal
            radius0: Some(10.0),
            ..Default::default()
        };
        let mut session = Som::builder()
            .config(cfg)
            .net(NetModel::ethernet_10g())
            .build()?;
        let (res, report) = session.fit_cluster(ClusterData::Dense {
            data: train_data.clone(),
            dim,
        })?;
        let secs = res.total.as_secs_f64();
        if t1.is_none() {
            t1 = Some(secs);
        }
        println!(
            "{:>6} {:>12.3?} {:>9.2}x {:>14} {:>12} {:>10.5}",
            ranks,
            res.total,
            t1.unwrap() / secs,
            fmt_bytes(report.bytes_sent as usize),
            report.messages_sent,
            res.final_qe()
        );
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "\nExpected (paper Fig. 8): near-linear speedup — communication is \
         one accumulator exchange per epoch, independent of data size."
    );
    if cores == 1 {
        println!(
            "NOTE: this host exposes {cores} core — rank threads time-slice \
             one CPU, so wall-clock speedup cannot appear here. The results \
             above still verify correctness + communication volume; the \
             modeled Fig. 8 speedup curve comes from \
             `cargo bench --bench fig8_multinode`."
        );
    }
    Ok(())
}
