//! Text-mining visualization — the paper's Fig. 9 / §5.3 workload:
//! a sparse term-document space trained on a *toroid emergent* map with
//! the sparse kernel, U-matrix exported for ESOM-style viewing.
//!
//! The paper used Reuters-21578 through Lucene (12,347 index terms in a
//! ~20k-dim space, 1–5% dense). Offline, we generate a Zipfian corpus
//! with planted topics that reproduces the structural claim: "dense
//! areas where index terms are close and form tight clusters ... large
//! barriers separating index terms into individual semantic regions."
//! See DESIGN.md §3 (substitutions).
//!
//! ```bash
//! cargo run --release --example text_mining          # scaled default
//! SOM_TEXT_FULL=1 cargo run --release --example text_mining
//! ```

use somoclu::coordinator::config::TrainConfig;
use somoclu::data::{zipf_corpus, CorpusSpec};
use somoclu::session::Som;
use somoclu::io::output::OutputWriter;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::som::{Cooling, MapType, Neighborhood};
use somoclu::util::memtrack::{fmt_bytes, MemRegion};
use somoclu::util::rng::Rng;
use somoclu::viz;

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::PathBuf::from("out/text");
    std::fs::create_dir_all(&out_dir)?;
    let full = std::env::var("SOM_TEXT_FULL").is_ok();

    // Paper: 12,347 instances, ~20k dims, 336x205 toroid emergent map,
    // 10 epochs, lr 1.0 -> 0.1 linear, radius 100 -> 1 linear,
    // noncompact gaussian. Scaled: 2,000 docs, 4,096 dims, 84x52 map.
    let spec = if full {
        CorpusSpec {
            docs: 12_347,
            vocab: 20_000,
            topics: 12,
            nnz_per_row: 400,
            topic_affinity: 0.7,
        }
    } else {
        CorpusSpec {
            docs: 2_000,
            vocab: 4_096,
            topics: 8,
            nnz_per_row: 80,
            topic_affinity: 0.75,
        }
    };
    let (rows, cols, radius0) = if full { (205, 336, 100.0) } else { (52, 84, 26.0) };

    let mut rng = Rng::new(13);
    let region = MemRegion::start();
    let (corpus, _topics) = zipf_corpus(&spec, &mut rng);
    println!(
        "corpus: {} docs x {} terms, {:.2}% dense, CSR {} (dense would be {})",
        corpus.rows,
        corpus.cols,
        corpus.density() * 100.0,
        fmt_bytes(corpus.heap_bytes()),
        fmt_bytes(corpus.rows * corpus.cols * 4),
    );

    let cfg = TrainConfig {
        rows,
        cols,
        epochs: 10,
        map_type: MapType::Toroid,
        neighborhood: Neighborhood::gaussian(false), // noncompact gaussian
        radius0: Some(radius0),
        radius_n: 1.0,
        radius_cooling: Cooling::Linear,
        scale0: 1.0,
        scale_n: 0.1,
        scale_cooling: Cooling::Linear,
        kernel: KernelType::SparseCpu,
        ..Default::default()
    };

    let t0 = std::time::Instant::now();
    let mut session = Som::builder().config(cfg.clone()).build()?;
    let res = session.fit_shard(DataShard::Sparse(corpus.view()))?;
    println!(
        "trained {}x{} toroid emergent map ({} nodes) in {:?}; peak memory {}",
        rows,
        cols,
        rows * cols,
        t0.elapsed(),
        fmt_bytes(region.peak_delta()),
    );
    for e in &res.epochs {
        println!("  epoch {:>2}  radius {:>7.2}  QE {:.5}", e.epoch, e.radius, e.qe);
    }

    let grid = cfg.grid();
    OutputWriter::new(out_dir.join("reuters_like"))
        .write_final(&grid, &res.codebook, &res.bmus, &res.umatrix)?;
    viz::write_heatmap_ppm(
        out_dir.join("umatrix.ppm"),
        &grid,
        &res.umatrix,
        6,
        Some(&res.bmus),
    )?;
    viz::write_heatmap_pgm(out_dir.join("umatrix.pgm"), &grid, &res.umatrix, 6)?;

    // Quantify the Fig. 9 claim: BMU-occupied nodes should sit in valleys
    // (low U) while barriers (high U) separate them.
    let mut hit = vec![false; grid.node_count()];
    for &b in &res.bmus {
        hit[b as usize] = true;
    }
    let (mut u_hit, mut n_hit, mut u_miss, mut n_miss) = (0.0f64, 0usize, 0.0f64, 0usize);
    for (u, h) in res.umatrix.iter().zip(&hit) {
        if *h {
            u_hit += *u as f64;
            n_hit += 1;
        } else {
            u_miss += *u as f64;
            n_miss += 1;
        }
    }
    println!(
        "U-matrix: mean height at occupied nodes {:.4} vs unoccupied {:.4} \
         ({} occupied / {} nodes) — clusters in valleys, barriers between",
        u_hit / n_hit.max(1) as f64,
        u_miss / n_miss.max(1) as f64,
        n_hit,
        grid.node_count()
    );
    println!("outputs in {}", out_dir.display());
    Ok(())
}
