//! RGB toy example — the paper's Figs. 2–4 workload: color vectors
//! self-organize on the map; the U-matrix shows cluster boundaries.
//!
//! Renders three images: the U-matrix heatmap, the learned codebook as
//! an RGB image (each neuron colored by its weight vector — the classic
//! "color map" figure), and a toroid variant (Fig. 2 is toroid).
//!
//! ```bash
//! cargo run --release --example rgb_clustering
//! ```

use std::io::Write;

use somoclu::data;
use somoclu::io::output::OutputWriter;
use somoclu::kernels::DataShard;
use somoclu::session::Som;
use somoclu::som::{Grid, MapType};
use somoclu::util::rng::Rng;
use somoclu::viz;

/// Write the codebook of a 3-dim (RGB) map directly as pixels.
fn write_codebook_rgb(
    path: &std::path::Path,
    grid: &Grid,
    codebook: &somoclu::som::Codebook,
    cell: usize,
) -> std::io::Result<()> {
    assert_eq!(codebook.dim, 3);
    let (w, h) = (grid.cols * cell, grid.rows * cell);
    let mut img = vec![0u8; w * h * 3];
    for r in 0..grid.rows {
        for c in 0..grid.cols {
            let row = codebook.row(grid.index(r, c));
            let rgb = [
                (row[0].clamp(0.0, 1.0) * 255.0) as u8,
                (row[1].clamp(0.0, 1.0) * 255.0) as u8,
                (row[2].clamp(0.0, 1.0) * 255.0) as u8,
            ];
            for py in 0..cell {
                for px in 0..cell {
                    let o = ((r * cell + py) * w + c * cell + px) * 3;
                    img[o..o + 3].copy_from_slice(&rgb);
                }
            }
        }
    }
    let f = std::fs::File::create(path)?;
    let mut out = std::io::BufWriter::new(f);
    write!(out, "P6\n{w} {h}\n255\n")?;
    out.write_all(&img)
}

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::PathBuf::from("out/rgb");
    std::fs::create_dir_all(&out_dir)?;
    let mut rng = Rng::new(11);
    let (rgb, _) = data::rgb_toy(1500, &mut rng);

    for (name, map_type) in [("planar", MapType::Planar), ("toroid", MapType::Toroid)] {
        let mut session = Som::builder()
            .map_size(30, 30)
            .epochs(12)
            .map_type(map_type)
            .build()?;
        let res = session.fit_shard(DataShard::Dense { data: &rgb, dim: 3 })?;
        let grid = session.grid().clone();

        let prefix = out_dir.join(name);
        OutputWriter::new(&prefix).write_final(&grid, &res.codebook, &res.bmus, &res.umatrix)?;
        viz::write_heatmap_ppm(
            out_dir.join(format!("{name}_umatrix.ppm")),
            &grid,
            &res.umatrix,
            8,
            Some(&res.bmus),
        )?;
        write_codebook_rgb(
            &out_dir.join(format!("{name}_codebook.ppm")),
            &grid,
            &res.codebook,
            8,
        )?;
        println!(
            "{name}: QE {:.4} -> {:.4} over {} epochs; outputs in {}",
            res.epochs[0].qe,
            res.final_qe(),
            res.epochs.len(),
            out_dir.display()
        );
    }
    Ok(())
}
